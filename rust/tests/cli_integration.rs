//! CLI integration: drive the `streamcom` binary end-to-end
//! (generate → run → sweep → bench memory) through real process spawns.

use std::path::PathBuf;
use std::process::Command;

fn exe() -> PathBuf {
    // target/<profile>/streamcom next to the test executable
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("streamcom");
    p
}

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(exe())
        .args(args)
        .current_dir(std::env::temp_dir())
        .output()
        .expect("spawn streamcom");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

fn run_with_stdin(args: &[&str], input: &str) -> (String, String, bool) {
    use std::io::Write;
    use std::process::Stdio;
    let mut child = Command::new(exe())
        .args(args)
        .current_dir(std::env::temp_dir())
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn streamcom");
    child
        .stdin
        .take()
        .expect("child stdin")
        .write_all(input.as_bytes())
        .expect("write stdin");
    let out = child.wait_with_output().expect("wait streamcom");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = run(&["help"]);
    assert!(ok);
    for cmd in ["generate", "run", "sweep", "bench", "serve", "convert"] {
        assert!(stdout.contains(cmd), "missing {cmd} in help");
    }
}

#[test]
fn unknown_command_fails_with_message() {
    let (_, stderr, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn generate_then_run_then_score() {
    let dir = std::env::temp_dir();
    let bin = dir.join(format!("sc_cli_{}.bin", std::process::id()));
    let bin_str = bin.to_str().unwrap();

    let (stdout, stderr, ok) = run(&[
        "generate",
        "--preset",
        "amazon-s",
        "--scale",
        "0.02",
        "--out",
        bin_str,
    ]);
    assert!(ok, "generate failed: {stderr}");
    assert!(stdout.contains("generated"));
    assert!(bin.is_file());

    let labels = dir.join(format!("sc_cli_{}.labels", std::process::id()));
    let (stdout, stderr, ok) = run(&[
        "run",
        "--input",
        bin_str,
        "--vmax",
        "32",
        "--out",
        labels.to_str().unwrap(),
        "--score",
    ]);
    assert!(ok, "run failed: {stderr}");
    assert!(stdout.contains("communities"), "{stdout}");
    assert!(stdout.contains("F1="), "score missing: {stdout}");
    assert!(labels.is_file());

    // parallel run on the same input
    let (stdout, stderr, ok) = run(&["run", "--input", bin_str, "--vmax", "32", "--parallel", "4"]);
    assert!(ok, "parallel run failed: {stderr}");
    assert!(stdout.contains("communities"));

    std::fs::remove_file(&bin).ok();
    std::fs::remove_file(&labels).ok();
    // generate also wrote .cmty and .txt siblings
    let stem = bin_str.trim_end_matches(".bin");
    std::fs::remove_file(format!("{stem}.cmty")).ok();
    std::fs::remove_file(format!("{stem}.txt")).ok();
}

#[test]
fn sweep_prints_ladder_and_winner() {
    let (stdout, stderr, ok) = run(&[
        "sweep",
        "--preset",
        "dblp-s",
        "--scale",
        "0.02",
        "--engine",
        "native",
    ]);
    assert!(ok, "sweep failed: {stderr}");
    assert!(stdout.contains("v_max"));
    assert!(stdout.contains("*"), "winner marker missing:\n{stdout}");
    assert!(stdout.contains("F1="));
}

#[test]
fn serve_answers_queries_and_scores_final_partition() {
    // SBM edge stream through the sharded service; queries piped on
    // stdin are answered against the evolving snapshot, and closing
    // stdin lets the ingest finish and print the scored partition
    let (stdout, stderr, ok) = run_with_stdin(
        &["serve", "--sbm", "6x40", "--shards", "2", "--vmax", "64", "--drain-every", "500"],
        "? 0\n? notanode\ntop 3\nstats\n",
    );
    assert!(ok, "serve failed: {stderr}");
    assert!(stdout.contains("node 0 → community"), "{stdout}");
    assert!(stdout.contains("! bad node id"), "typo must not kill serve: {stdout}");
    assert!(stdout.contains("shards=2"), "{stdout}");
    assert!(stdout.contains("final:"), "{stdout}");
    assert!(stdout.contains("F1="), "final score missing: {stdout}");
}

#[test]
fn serve_stats_report_horizon_and_leader_partitions() {
    // --horizon 0 is the CLI spelling of "unbounded" (normalised at
    // service start-up); --leaders picks the committed-base partition
    // count and the stats line must surface both
    let (stdout, stderr, ok) = run_with_stdin(
        &[
            "serve", "--sbm", "6x40", "--shards", "2", "--leaders", "3", "--vmax", "64",
            "--drain-every", "500", "--horizon", "0",
        ],
        "stats\n",
    );
    assert!(ok, "serve failed: {stderr}");
    assert!(stdout.contains("shards=2"), "{stdout}");
    assert!(stdout.contains("leaders=3"), "{stdout}");
    assert!(stdout.contains("horizon=unbounded"), "{stdout}");
    assert!(stdout.contains("delta_last="), "{stdout}");
    assert!(stdout.contains("per-leader r/c/f=["), "{stdout}");
    assert!(stdout.contains("pool hit/miss="), "{stdout}");
    assert!(stdout.contains("recycled="), "{stdout}");

    // a bounded horizon reads back verbatim, and leaders default to one
    // per shard
    let (stdout, stderr, ok) = run_with_stdin(
        &["serve", "--sbm", "6x40", "--shards", "2", "--vmax", "64", "--horizon", "5000"],
        "stats\n",
    );
    assert!(ok, "serve failed: {stderr}");
    assert!(stdout.contains("leaders=2"), "{stdout}");
    assert!(stdout.contains("horizon=5000"), "{stdout}");
}

#[test]
fn serve_rejects_malformed_horizon() {
    let (_, stderr, ok) = run_with_stdin(
        &["serve", "--sbm", "4x20", "--horizon", "lots"],
        "",
    );
    assert!(!ok, "malformed --horizon must fail fast");
    assert!(stderr.contains("horizon"), "{stderr}");
}

#[test]
fn serve_wal_dir_then_resume_recovers_the_stream() {
    // first run: durability on — the stats line surfaces the WAL
    // counters, and the clean finish syncs the full stream to disk
    let dir = std::env::temp_dir().join(format!("sc_wal_cli_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let dir_str = dir.to_str().unwrap();
    let (stdout, stderr, ok) = run_with_stdin(
        &["serve", "--sbm", "6x40", "--shards", "2", "--vmax", "64", "--wal-dir", dir_str],
        "stats\n",
    );
    assert!(ok, "serve --wal-dir failed: {stderr}");
    assert!(stdout.contains("wal="), "{stdout}");
    assert!(stdout.contains("ckpts="), "{stdout}");
    assert!(stdout.contains("recovered_epochs="), "{stdout}");
    assert!(stdout.contains("final:"), "{stdout}");

    // second run: --resume recovers the whole logged stream, reports
    // the recovered position, skips the already-ingested prefix, and
    // still reaches a final partition
    let (stdout, stderr, ok) = run_with_stdin(
        &[
            "serve", "--sbm", "6x40", "--shards", "2", "--vmax", "64", "--wal-dir", dir_str,
            "--resume",
        ],
        "stats\n",
    );
    assert!(ok, "serve --resume failed: {stderr}");
    assert!(stdout.contains("resume: recovered to t="), "{stdout}");
    assert!(stdout.contains("final:"), "{stdout}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_dynamic_mode_still_speaks_event_protocol() {
    let (stdout, _, ok) = run_with_stdin(
        &["serve", "--dynamic", "--vmax", "8"],
        "+ 0 1\n+ 1 2\n?\n- 0 1\n?\nq\n",
    );
    assert!(ok);
    assert!(stdout.contains("live_edges=2"), "{stdout}");
    assert!(stdout.contains("live_edges=1"), "{stdout}");
    assert!(stdout.contains("bye:"), "{stdout}");
}

#[test]
fn convert_roundtrips_text_and_binary() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let bin = dir.join(format!("sc_conv_{pid}.bin"));
    let bin_str = bin.to_str().unwrap();
    let (_, stderr, ok) = run(&[
        "generate", "--preset", "amazon-s", "--scale", "0.02", "--out", bin_str,
    ]);
    assert!(ok, "generate failed: {stderr}");
    let stem = bin_str.trim_end_matches(".bin");
    let txt = format!("{stem}.txt");

    // text → binary (small segments so the file is multi-segment) —
    // convert verifies the round trip itself before reporting
    let bin2 = dir.join(format!("sc_conv_{pid}_rt.bin"));
    let (stdout, stderr, ok) = run(&[
        "convert", "--input", &txt, "--out", bin2.to_str().unwrap(), "--seg-records", "512",
    ]);
    assert!(ok, "convert to binary failed: {stderr}");
    assert!(stdout.contains("round trip verified"), "{stdout}");
    assert!(stdout.contains("segments"), "{stdout}");

    // binary → text, then the converted file still runs end-to-end
    let txt2 = dir.join(format!("sc_conv_{pid}_rt.txt"));
    let (stdout, stderr, ok) =
        run(&["convert", "--input", bin2.to_str().unwrap(), "--out", txt2.to_str().unwrap()]);
    assert!(ok, "convert to text failed: {stderr}");
    assert!(stdout.contains("round trip verified"), "{stdout}");
    let (stdout, stderr, ok) = run(&["run", "--input", txt2.to_str().unwrap(), "--vmax", "32"]);
    assert!(ok, "run on converted file failed: {stderr}");
    assert!(stdout.contains("communities"), "{stdout}");

    for p in [bin_str.to_string(), txt, format!("{stem}.cmty")] {
        std::fs::remove_file(&p).ok();
    }
    std::fs::remove_file(&bin2).ok();
    std::fs::remove_file(&txt2).ok();
}

#[test]
fn serve_parallel_readers_scan_the_input_file() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let bin = dir.join(format!("sc_scan_{pid}.bin"));
    let bin_str = bin.to_str().unwrap();
    let (_, stderr, ok) = run(&[
        "generate", "--preset", "amazon-s", "--scale", "0.02", "--out", bin_str,
    ]);
    assert!(ok, "generate failed: {stderr}");
    // scan the text sibling: text ranges split at newlines whatever the
    // file size, so 3 readers stay 3 (a small binary file can clamp to
    // its segment count)
    let stem = bin_str.trim_end_matches(".bin");
    let txt = format!("{stem}.txt");

    let (stdout, stderr, ok) = run_with_stdin(
        &[
            "serve", "--input", &txt, "--readers", "3", "--shards", "2", "--vmax", "64",
            "--drain-every", "500",
        ],
        "stats\n",
    );
    assert!(ok, "serve --readers failed: {stderr}");
    assert!(stdout.contains("scan: 3 reader threads"), "{stdout}");
    assert!(stdout.contains("final:"), "{stdout}");
    assert!(stdout.contains("scan: readers=3"), "{stdout}");

    // --readers needs a file to scan
    let (_, stderr, ok) =
        run_with_stdin(&["serve", "--sbm", "4x20", "--readers", "2"], "");
    assert!(!ok, "--readers without --input must fail fast");
    assert!(stderr.contains("--readers"), "{stderr}");

    std::fs::remove_file(&bin).ok();
    std::fs::remove_file(&txt).ok();
    std::fs::remove_file(format!("{stem}.cmty")).ok();
}

#[test]
fn serve_mmap_scans_the_binary_input() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let src = dir.join(format!("sc_mmap_{pid}.bin"));
    let src_str = src.to_str().unwrap();
    let (_, stderr, ok) = run(&[
        "generate", "--preset", "amazon-s", "--scale", "0.02", "--out", src_str,
    ]);
    assert!(ok, "generate failed: {stderr}");
    let stem = src_str.trim_end_matches(".bin");
    // rewrite with small segments so the file splits across 2 readers
    let bin = dir.join(format!("sc_mmap_{pid}_seg.bin"));
    let bin_str = bin.to_str().unwrap();
    let (stdout, stderr, ok) = run(&[
        "convert", "--input", src_str, "--out", bin_str, "--seg-records", "512", "--mmap",
    ]);
    assert!(ok, "convert --mmap failed: {stderr}");
    assert!(stdout.contains("round trip verified (mmap reads)"), "{stdout}");

    let (stdout, stderr, ok) = run_with_stdin(
        &[
            "serve", "--input", bin_str, "--mmap", "--readers", "2", "--shards", "2", "--vmax",
            "64",
        ],
        "stats\n",
    );
    assert!(ok, "serve --mmap failed: {stderr}");
    assert!(stdout.contains("scan: 2 reader threads"), "{stdout}");
    assert!(stdout.contains("final:"), "{stdout}");
    // the footer reports the transport honestly: mapped on unix,
    // buffered fallback elsewhere
    let want = if cfg!(unix) { "mmap=on" } else { "mmap=off" };
    assert!(stdout.contains(want), "{stdout}");

    // --readers 0 under --mmap auto-detects the machine's parallelism
    let (stdout, stderr, ok) =
        run_with_stdin(&["serve", "--input", bin_str, "--mmap", "--shards", "2"], "");
    assert!(ok, "serve --mmap auto-readers failed: {stderr}");
    assert!(stdout.contains("auto-detected"), "{stdout}");
    assert!(stdout.contains("final:"), "{stdout}");

    // --mmap needs a file to map
    let (_, stderr, ok) = run_with_stdin(&["serve", "--sbm", "4x20", "--mmap"], "");
    assert!(!ok, "--mmap without --input must fail fast");
    assert!(stderr.contains("--mmap"), "{stderr}");

    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&bin).ok();
    std::fs::remove_file(format!("{stem}.txt")).ok();
    std::fs::remove_file(format!("{stem}.cmty")).ok();
}

#[test]
fn serve_routes_binary_scans_directly_and_gates_the_flag() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let bin = dir.join(format!("sc_route_{pid}.bin"));
    let bin_str = bin.to_str().unwrap();
    let (_, stderr, ok) = run(&[
        "generate", "--preset", "amazon-s", "--scale", "0.02", "--out", bin_str,
    ]);
    assert!(ok, "generate failed: {stderr}");
    let stem = bin_str.trim_end_matches(".bin");

    // --route auto on a plain binary scan picks direct dispatch
    let (stdout, stderr, ok) = run_with_stdin(
        &["serve", "--input", bin_str, "--readers", "2", "--shards", "2", "--vmax", "64"],
        "stats\n",
    );
    assert!(ok, "serve direct failed: {stderr}");
    assert!(stdout.contains("routing in the readers (direct dispatch)"), "{stdout}");
    assert!(stdout.contains("route=direct"), "{stdout}");
    assert!(stdout.contains("final:"), "{stdout}");

    // forcing the funnel on the same invocation is honoured
    let (stdout, stderr, ok) = run_with_stdin(
        &[
            "serve", "--input", bin_str, "--readers", "2", "--shards", "2", "--vmax", "64",
            "--route", "funnel",
        ],
        "",
    );
    assert!(ok, "serve --route funnel failed: {stderr}");
    assert!(stdout.contains("route=funnel"), "{stdout}");

    // --route direct + --wal-dir compose: the readers append routed
    // chunks to per-reader WAL lanes before enqueueing, and the footer
    // says so
    let wal = dir.join(format!("sc_route_wal_{pid}"));
    let (stdout, stderr, ok) = run_with_stdin(
        &[
            "serve", "--input", bin_str, "--readers", "2", "--shards", "2", "--vmax", "64",
            "--route", "direct", "--wal-dir", wal.to_str().unwrap(),
        ],
        "stats\n",
    );
    assert!(ok, "serve --route direct --wal-dir failed: {stderr}");
    assert!(stdout.contains("route=direct"), "{stdout}");
    assert!(stdout.contains("wal: durable direct dispatch"), "{stdout}");
    assert!(stdout.contains("final:"), "{stdout}");

    // ...and the lanes the direct run left behind resume cleanly (the
    // resume path itself rides the funnel's positional slicing)
    let (stdout, stderr, ok) = run_with_stdin(
        &[
            "serve", "--input", bin_str, "--shards", "2", "--vmax", "64", "--wal-dir",
            wal.to_str().unwrap(), "--resume",
        ],
        "",
    );
    assert!(ok, "resume from direct lanes failed: {stderr}");
    assert!(stdout.contains("resume: recovered to t="), "{stdout}");
    assert!(stdout.contains("final:"), "{stdout}");

    // unknown spellings are rejected up front
    let (_, stderr, ok) =
        run_with_stdin(&["serve", "--input", bin_str, "--route", "sideways"], "");
    assert!(!ok, "--route sideways must fail fast");
    assert!(stderr.contains("--route expects"), "{stderr}");

    std::fs::remove_file(&bin).ok();
    std::fs::remove_dir_all(&wal).ok();
    std::fs::remove_file(format!("{stem}.txt")).ok();
    std::fs::remove_file(format!("{stem}.cmty")).ok();
}

/// Like [`run_with_stdin`] but returns the raw exit code, for tests
/// that pin the error contract (one typed line on stderr, exit 1).
fn run_with_stdin_code(args: &[&str], input: &str) -> (String, String, Option<i32>) {
    use std::io::Write;
    use std::process::Stdio;
    let mut child = Command::new(exe())
        .args(args)
        .current_dir(std::env::temp_dir())
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn streamcom");
    child
        .stdin
        .take()
        .expect("child stdin")
        .write_all(input.as_bytes())
        .expect("write stdin");
    let out = child.wait_with_output().expect("wait streamcom");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.code(),
    )
}

#[test]
fn serve_failures_exit_with_one_typed_error_line() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();

    // a resume that contradicts the durable contract (no WAL directory
    // to resume from): exactly one "error: ..." line on stderr, exit 1
    let (_, stderr, code) = run_with_stdin_code(
        &["serve", "--sbm", "6x40", "--shards", "2", "--vmax", "64", "--resume"],
        "",
    );
    assert_eq!(code, Some(1), "resume without --wal-dir must exit 1: {stderr}");
    let lines: Vec<&str> = stderr.lines().filter(|l| !l.trim().is_empty()).collect();
    assert_eq!(lines.len(), 1, "exactly one error line, got: {stderr}");
    assert!(
        lines[0].starts_with("error: resume: durable state mismatch"),
        "{stderr}"
    );

    // a reader that dies mid-scan (corrupt segment body) on the direct
    // route: the service drains, and serve exits with the typed
    // reader error instead of panicking
    let bin = dir.join(format!("sc_err_scan_{pid}.bin"));
    let bin_str = bin.to_str().unwrap();
    let (_, stderr, ok) = run(&[
        "generate", "--preset", "amazon-s", "--scale", "0.02", "--out", bin_str,
    ]);
    assert!(ok, "generate failed: {stderr}");
    let mut bytes = std::fs::read(&bin).expect("read generated binary");
    let tail = bytes.len() - 10;
    bytes[tail] ^= 0x5A; // damage the last segment's body
    std::fs::write(&bin, &bytes).expect("write damaged binary");
    let (_, stderr, code) = run_with_stdin_code(
        &[
            "serve", "--input", bin_str, "--readers", "2", "--shards", "2", "--vmax", "64",
            "--route", "direct",
        ],
        "",
    );
    assert_eq!(code, Some(1), "reader death must exit 1: {stderr}");
    // the fault is reported once when it happens ("service: ...") and
    // once, typed, as the exit line — exactly one "error: ..." line,
    // and it is the last thing on stderr
    let lines: Vec<&str> = stderr.lines().filter(|l| !l.trim().is_empty()).collect();
    let errors: Vec<&&str> = lines.iter().filter(|l| l.starts_with("error: ")).collect();
    assert_eq!(errors.len(), 1, "exactly one typed error line, got: {stderr}");
    assert!(errors[0].starts_with("error: scan failed: reader "), "{stderr}");
    assert_eq!(*errors[0], *lines.last().unwrap(), "error must be the exit line: {stderr}");

    std::fs::remove_file(&bin).ok();
    let stem = bin_str.trim_end_matches(".bin");
    std::fs::remove_file(format!("{stem}.txt")).ok();
    std::fs::remove_file(format!("{stem}.cmty")).ok();
}

#[test]
fn bench_service_writes_machine_readable_json() {
    let dir = std::env::temp_dir();
    let json_path = dir.join(format!("sc_bench_{}.json", std::process::id()));
    let json_str = json_path.to_str().unwrap();

    let (stdout, stderr, ok) = run(&[
        "bench", "service", "--scale", "0.03", "--out", json_str, "--json",
    ]);
    assert!(ok, "bench service failed: {stderr}");
    assert!(stdout.contains("service bench"), "{stdout}");
    assert!(stdout.contains("delta_last"), "{stdout}");
    assert!(stdout.contains("ingest microbench"), "{stdout}");
    assert!(stdout.contains("rmw/kedge"), "{stdout}");
    assert!(stdout.contains("parallel scan"), "{stdout}");
    assert!(stdout.contains("mmap scan"), "{stdout}");
    assert!(stdout.contains("routing:"), "{stdout}");
    let json = std::fs::read_to_string(&json_path).expect("BENCH_service.json written");
    assert!(json.contains("\"bench\": \"service\""), "{json}");
    assert!(json.contains("\"measured\": true"), "{json}");
    assert!(json.contains("\"edges_per_sec\""), "{json}");
    assert!(json.contains("\"per_leader\""), "{json}");
    assert!(json.contains("\"ingest\""), "{json}");
    assert!(json.contains("\"pool_misses\""), "{json}");
    assert!(json.contains("\"readers\""), "{json}");
    assert!(json.contains("\"mmap\""), "{json}");
    assert!(json.contains("\"mapped\""), "{json}");
    assert!(json.contains("\"routing\""), "{json}");
    assert!(json.contains("\"labels_match\": true"), "{json}");
    assert!(!json.contains("\"labels_match\": false"), "{json}");
    std::fs::remove_file(&json_path).ok();

    // without --json the table still renders and nothing is written
    let (stdout, stderr, ok) = run(&["bench", "service", "--scale", "0.03"]);
    assert!(ok, "bench service failed: {stderr}");
    assert!(stdout.contains("service bench"), "{stdout}");
    assert!(!json_path.exists());
}

#[test]
fn bench_memory_prints_ratio_table() {
    let (stdout, stderr, ok) = run(&["bench", "memory", "--scale", "0.01"]);
    assert!(ok, "bench memory failed: {stderr}");
    assert!(stdout.contains("edge list"));
    assert!(stdout.contains("STR sketch"));
    assert!(stdout.contains('x'));
}
