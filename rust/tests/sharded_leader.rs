//! Sharded-leader properties, end to end through the public service
//! API:
//!
//! * **Leader-count invariance** — the leader partition count is a
//!   deployment-shape knob: under an unbounded (or never-binding)
//!   commit horizon the final partition is bit-identical to
//!   `run_parallel` whatever `leaders` is, across shard counts and
//!   drain cadences. (The bounded-horizon equivalence — merging K base
//!   slices ≡ the single-leader base for the same committed epochs —
//!   is deterministic only without thread timing, so it lives as the
//!   in-crate property `sharded_base_merge_equals_single_leader…` in
//!   `service::snapshot`.)
//! * **Delta payload flatness** — the per-drain delta payload
//!   (replayed suffix + frozen records + commit headers) is
//!   O(new epoch deltas): on a long high-cross stream drained at a
//!   fixed cadence it stays under an analytic bound derived from the
//!   cadence alone, while the committed base grows far past that bound
//!   — the "drains no longer ship the base" claim, observable.
//! * **Per-leader accounting** — retained/committed/freed bytes per
//!   leader partition always sum to the service-wide figures.

use streamcom::coordinator::parallel::{run_parallel, ParallelConfig};
use streamcom::graph::edge::Edge;
use streamcom::graph::generators::sbm::{self, SbmConfig};
use streamcom::service::{ClusterService, CommitHorizon, ServiceConfig};
use streamcom::util::proptest::property;
use streamcom::util::rng::Xoshiro256;

/// Random multigraph edge stream over `size` nodes, in random order.
fn random_stream(rng: &mut Xoshiro256, size: usize) -> (usize, Vec<Edge>) {
    let n = size.max(2);
    let m = size * 4;
    let mut edges: Vec<Edge> = (0..m)
        .map(|_| {
            let u = rng.range(0, n) as u32;
            let mut v = rng.range(0, n) as u32;
            if u == v {
                v = (v + 1) % n as u32;
            }
            Edge::new(u, v)
        })
        .collect();
    rng.shuffle(&mut edges);
    (n, edges)
}

fn pad(mut labels: Vec<u32>, n: usize) -> Vec<u32> {
    while labels.len() < n {
        labels.push(labels.len() as u32);
    }
    labels
}

#[test]
fn leader_count_is_invariant_and_finals_match_batch() {
    property("sharded leader invariance", 6, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let v_max = 1 + rng.next_below(200);
        for shards in [2usize, 4] {
            let full = pad(
                run_parallel(n, &edges, &ParallelConfig::new(shards, v_max)).labels(),
                n,
            );
            for leaders in [1usize, 3] {
                for cadence in [1u64, 17] {
                    // alternate between the default unbounded horizon
                    // and a bounded one at least as long as the stream:
                    // neither ever commits, so the sharded leaders stay
                    // empty and finals must equal the batch run exactly
                    let horizon = if (cadence + leaders as u64) % 2 == 0 {
                        CommitHorizon::Unbounded
                    } else {
                        CommitHorizon::Edges(edges.len() as u64 + 1 + rng.next_below(50))
                    };
                    let mut cfg = ServiceConfig::new(shards, v_max);
                    cfg.leaders = leaders;
                    cfg.drain_every = cadence;
                    cfg.chunk_size = 1 + rng.next_below(32) as usize;
                    cfg.horizon = horizon;
                    let mut svc = ClusterService::start(cfg);
                    let handle = svc.handle();

                    let half = edges.len() / 2;
                    svc.push_chunk(&edges[..half]);
                    svc.quiesce();
                    svc.push_chunk(&edges[half..]);
                    svc.quiesce();
                    let res = svc.finish();
                    let got = res.snapshot.labels_padded(n);
                    if got != full {
                        let diff = got.iter().zip(&full).filter(|(a, b)| a != b).count();
                        return Err(format!(
                            "shards={shards} leaders={leaders} cadence={cadence} \
                             v_max={v_max}: final diverged from batch at {diff} nodes"
                        ));
                    }

                    let s = handle.stats();
                    if s.leaders != leaders {
                        return Err(format!(
                            "stats report {} leaders, configured {leaders}",
                            s.leaders
                        ));
                    }
                    if s.cross_committed != 0 || s.committed_bytes_total() != 0 {
                        return Err(format!(
                            "never-binding horizon committed {} edges / {} B",
                            s.cross_committed,
                            s.committed_bytes_total()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Strongly separated SBM over 4 shards: ~3/4 of all edges are
/// cross-shard, so the committed base grows with the stream while the
/// per-drain work stays at the chunk size.
fn high_cross_workload() -> streamcom::graph::generators::GeneratedGraph {
    sbm::generate(&SbmConfig::equal(10, 60, 0.4, 0.002, 71))
}

#[test]
fn delta_payload_stays_flat_while_committed_base_grows() {
    let g = high_cross_workload();
    let h = 256u64;
    let chunk = 200usize;
    let mut cfg = ServiceConfig::new(4, 128);
    cfg.chunk_size = 32;
    cfg.drain_every = u64::MAX; // drains happen exactly at our quiesces
    cfg.horizon = CommitHorizon::Edges(h);
    let mut svc = ClusterService::start(cfg);
    let handle = svc.handle();

    let mut max_payload = 0u64;
    let mut last_committed_bytes = 0u64;
    let mut bound = 0u64;
    for part in g.edges.edges.chunks(chunk) {
        svc.push_chunk(part);
        svc.quiesce();
        let s = handle.stats();
        // analytic per-drain bound, from the cadence alone: at most
        // `chunk` new cross edges in (8 B each), two frozen records per
        // edge out (8 B each), and one 24 B header per epoch the drain
        // can commit (≤ chunk/epoch_len + 2, the +2 covering epochs
        // left pending by earlier drains)
        bound = chunk as u64 * (8 + 16) + (chunk as u64 / s.cross_epoch_len + 2) * 24;
        assert!(
            s.delta_last_bytes <= bound,
            "drain payload {} exceeded the delta bound {bound} at t={}",
            s.delta_last_bytes,
            s.edges_ingested
        );
        max_payload = max_payload.max(s.delta_last_bytes);
        let committed_bytes = s.committed_bytes_total();
        assert!(
            committed_bytes >= last_committed_bytes,
            "committed base shrank: {committed_bytes} < {last_committed_bytes}"
        );
        last_committed_bytes = committed_bytes;
        // payload and committed state always reconcile per leader
        assert_eq!(
            s.per_leader.iter().map(|l| l.retained_bytes).sum::<u64>(),
            s.cross_log_bytes
        );
    }

    let s = handle.stats();
    // the claim: the base grew far past what any single drain shipped
    assert!(
        s.cross_committed > 0 && s.committed_bytes_total() >= 5 * bound,
        "workload too small to show the gap: committed {} B vs bound {bound} B",
        s.committed_bytes_total()
    );
    assert!(
        max_payload <= bound,
        "max drain payload {max_payload} vs bound {bound}"
    );
    // committed-base bytes are exactly the folded frozen records
    assert_eq!(s.committed_bytes_total(), s.cross_committed * 16);

    // bounded finality keeps the coverage invariants
    let res = svc.finish();
    assert_eq!(res.edges_ingested, g.m() as u64);
    assert_eq!(res.snapshot.edges(), g.m() as u64);
    assert_eq!(res.state().total_volume(), 2 * g.m() as u64);
}

#[test]
fn per_leader_accounting_partitions_the_totals() {
    let g = high_cross_workload();
    let mut cfg = ServiceConfig::new(4, 128);
    cfg.leaders = 3; // deliberately ≠ shards: partitions are independent
    cfg.chunk_size = 64;
    cfg.drain_every = 512;
    cfg.horizon = CommitHorizon::Edges(300);
    let mut svc = ClusterService::start(cfg);
    let handle = svc.handle();

    let half = g.m() / 2;
    for stop in [half, g.m()] {
        let start = if stop == half { 0 } else { half };
        svc.push_chunk(&g.edges.edges[start..stop]);
        svc.quiesce();
        let s = handle.stats();
        assert_eq!(s.leaders, 3);
        assert_eq!(s.per_leader.len(), 3);
        assert_eq!(
            s.per_leader.iter().map(|l| l.retained_bytes).sum::<u64>(),
            s.cross_log_bytes,
            "retained bytes must partition the resident log"
        );
        assert_eq!(
            s.per_leader.iter().map(|l| l.freed_bytes).sum::<u64>(),
            s.cross_freed_bytes,
            "freed bytes must partition the freed total"
        );
        assert_eq!(
            s.committed_bytes_total(),
            s.cross_committed * 16,
            "committed bytes must equal the folded records"
        );
    }
    let s = handle.stats();
    assert!(s.cross_committed > 0, "workload never committed an epoch");
    assert!(
        s.per_leader.iter().filter(|l| l.committed_bytes > 0).count() > 1,
        "commits all landed in one partition: {:?}",
        s.per_leader
    );
    svc.finish();
}
