//! Integration: the PJRT-executed AOT artifacts must agree with the
//! native Rust implementations of the same math.
//!
//! The whole file is gated on the `pjrt` feature: the default offline
//! build has no XLA bindings, so there is no runtime to integrate with
//! (`runtime::PjrtEngine` is a stub that fails at load) and these
//! tests compile to nothing. **With the feature enabled** they require
//! `make artifacts` to have run, and missing artifacts make them fail
//! with a clear message rather than skip — a silently-skipped runtime
//! path would defeat the point of the three-layer architecture.

#![cfg(feature = "pjrt")]

use streamcom::coordinator::selection::{
    pad_sweep, select, MetricEngine, NativeEngine, SelectionRule, NUM_SWEEPS, VOLUME_BUCKETS,
};
use streamcom::coordinator::sweep::MultiSweep;
use streamcom::graph::generators::sbm::{self, SbmConfig};
use streamcom::metrics::modularity;
use streamcom::metrics::nmi::{contingency_table, nmi_from_table, NmiNorm};
use streamcom::runtime::{PjrtEngine, PjrtRuntime};
use streamcom::util::rng::Xoshiro256;

fn runtime() -> PjrtRuntime {
    PjrtRuntime::load_default().expect(
        "PJRT runtime failed to load — run `make artifacts` before `cargo test`",
    )
}

fn finished_sweep() -> MultiSweep {
    let g = sbm::generate(&SbmConfig::equal(12, 40, 0.3, 0.004, 77));
    let mut sweep = MultiSweep::new(g.n(), MultiSweep::geometric_ladder(4, 8));
    sweep.process_chunk(&g.edges.edges);
    sweep
}

#[test]
fn pjrt_sweep_metrics_match_native() {
    let sweep = finished_sweep();
    let padded = pad_sweep(&sweep, NUM_SWEEPS, VOLUME_BUCKETS);
    let native = NativeEngine.sweep_metrics(
        &padded.vols,
        &padded.sizes,
        &padded.w,
        padded.a,
        padded.k,
    );
    let mut engine = PjrtEngine::new(runtime());
    let pjrt = engine.sweep_metrics(&padded.vols, &padded.sizes, &padded.w, padded.a, padded.k);
    assert_eq!(native.len(), pjrt.len());
    for (i, (n, p)) in native.iter().zip(&pjrt).enumerate() {
        let close = |a: f32, b: f32, tol: f32| (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()));
        assert!(close(n.entropy, p.entropy, 1e-4), "row {i} entropy {n:?} vs {p:?}");
        assert!(close(n.density, p.density, 1e-4), "row {i} density {n:?} vs {p:?}");
        assert!(close(n.balance, p.balance, 1e-4), "row {i} balance {n:?} vs {p:?}");
        assert_eq!(n.ncomms, p.ncomms, "row {i} ncomms");
        assert!(close(n.density_score, p.density_score, 1e-4), "row {i} dscore");
        assert!(close(n.balance_score, p.balance_score, 1e-4), "row {i} bscore");
    }
}

#[test]
fn pjrt_selection_agrees_with_native() {
    let sweep = finished_sweep();
    let (w_native, _) = select(&sweep, &mut NativeEngine, SelectionRule::DensityScore);
    let mut engine = PjrtEngine::new(runtime());
    let (w_pjrt, _) = select(&sweep, &mut engine, SelectionRule::DensityScore);
    assert_eq!(w_native, w_pjrt);
    assert_eq!(engine.calls, 1);
}

#[test]
fn pjrt_modularity_matches_native_partials() {
    let rt = runtime();
    let g = sbm::generate(&SbmConfig::equal(6, 30, 0.35, 0.01, 5));
    let labels = streamcom::coordinator::algorithm::cluster_edges(g.n(), &g.edges.edges, 64);

    // build one padded block (graph is small enough to fit)
    const B: usize = 4096;
    const K: usize = 4096;
    assert!(g.m() <= B);
    let mut ci = vec![0i32; B];
    let mut cj = vec![0i32; B];
    let mut mask = vec![0f32; B];
    // labels are node-id-space; remap to dense < K
    let mut dense = labels.clone();
    streamcom::baselines::normalize_labels(&mut dense);
    for (b, e) in g.edges.edges.iter().enumerate() {
        ci[b] = dense[e.u as usize] as i32;
        cj[b] = dense[e.v as usize] as i32;
        mask[b] = 1.0;
    }
    let mut vols = vec![0f32; K];
    for e in &g.edges.edges {
        vols[dense[e.u as usize] as usize] += 1.0;
        vols[dense[e.v as usize] as usize] += 1.0;
    }
    let (intra, volsq) = rt.modularity_partials(&ci, &cj, &mask, &vols).unwrap();
    let (n_intra, n_volsq) = modularity::partials(&g.edges.edges, &labels);
    assert!((intra - n_intra).abs() < 1e-3, "{intra} vs {n_intra}");
    assert!(
        (volsq - n_volsq).abs() / n_volsq.max(1.0) < 1e-5,
        "{volsq} vs {n_volsq}"
    );
    let q_pjrt = modularity::combine_partials(intra, volsq, g.m() as u64);
    let q_native = modularity::modularity(g.n(), &g.edges.edges, &labels);
    assert!((q_pjrt - q_native).abs() < 1e-5, "{q_pjrt} vs {q_native}");
}

#[test]
fn pjrt_nmi_matches_native() {
    let rt = runtime();
    let mut rng = Xoshiro256::new(9);
    let n = 3000;
    let a: Vec<u32> = (0..n).map(|_| rng.range(0, 40) as u32).collect();
    let b: Vec<u32> = a
        .iter()
        .map(|&x| if rng.bernoulli(0.75) { x } else { rng.range(0, 40) as u32 })
        .collect();
    let table = contingency_table(&a, &b, 256);
    let native = nmi_from_table(&table, 256, NmiNorm::Avg);
    let pjrt = rt.nmi(&table).unwrap();
    assert!((native - pjrt).abs() < 1e-4, "{native} vs {pjrt}");
}

#[test]
fn pjrt_runtime_reports_cpu_platform() {
    let rt = runtime();
    let platform = rt.platform().to_lowercase();
    assert!(platform.contains("cpu") || platform.contains("host"), "{platform}");
}

#[test]
fn pjrt_rejects_wrong_shapes() {
    let rt = runtime();
    assert!(rt.sweep_metrics(&[0.0; 8], &[0.0; 8], &[0.0; 8]).is_err());
    assert!(rt.nmi(&[0.0; 4]).is_err());
}
