//! Routing-mode property suite: the direct sharded dispatch path
//! (readers route, per-shard delivery in file order) must be a pure
//! transport choice — at every readers × shards combination, on both
//! golden streams, its final partition is bit-identical to the
//! funneled scan and to the in-memory baseline. The suite also pins
//! the mechanism that makes this hold for the cross lane: epoch-seal
//! counts depend only on the cross arrival sequence, so they are
//! reader-count-invariant.

use std::path::PathBuf;

use streamcom::graph::edge::EdgeList;
use streamcom::graph::generators::lfr::{self, LfrConfig};
use streamcom::graph::generators::sbm::{self, SbmConfig};
use streamcom::graph::io::write_binary_edges_with;
use streamcom::service::{ClusterService, CommitHorizon, ServiceConfig};
use streamcom::stream::pscan::{DirectScan, ParallelScanner};

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("streamcom_routing_{}_{name}", std::process::id()));
    p
}

/// Small-chunk service config: drains off so every run is the pure
/// terminal replay (the exactness domain the parity contract lives in).
fn cfg(shards: usize) -> ServiceConfig {
    let mut c = ServiceConfig::new(shards, 64);
    c.chunk_size = 256;
    c.drain_every = 0;
    c
}

/// In-memory reference partition for `el` at `shards` workers.
fn baseline(el: &EdgeList, shards: usize) -> Vec<u32> {
    let mut svc = ClusterService::start(cfg(shards));
    for chunk in el.edges.chunks(4096) {
        svc.push_chunk(chunk);
    }
    svc.finish().labels()
}

/// The tentpole invariant: funnel scan ≡ direct buffered ≡ direct mmap
/// ≡ in-memory, bit for bit, across readers {1,2,4} × shards {1,2,4}.
fn assert_routing_parity(name: &str, el: &EdgeList) {
    let path = tmp(name);
    // small segments so every swept reader count owns several segments
    write_binary_edges_with(&path, el, 64).expect("write golden binary");
    for shards in [1usize, 2, 4] {
        let want = baseline(el, shards);
        for readers in [1usize, 2, 4] {
            // funnel: ordered sequencer + single routing thread
            let mut svc = ClusterService::start(cfg(shards));
            let mut scanner =
                ParallelScanner::open(&path, readers, 512).expect("open funnel scan");
            svc.ingest(&mut scanner, 512);
            assert!(scanner.take_error().is_none());
            assert_eq!(
                svc.finish().labels(),
                want,
                "{name}: funnel diverged at readers={readers} shards={shards}"
            );

            // direct, buffered readers
            let mut svc = ClusterService::start(cfg(shards));
            let mut scan =
                DirectScan::open(&path, readers, 512, shards, None).expect("open direct scan");
            svc.ingest_direct(&mut scan);
            assert!(scan.take_error().is_none());
            assert_eq!(
                svc.finish().labels(),
                want,
                "{name}: direct diverged at readers={readers} shards={shards}"
            );

            // direct, one shared mapping (buffered fallback off-unix —
            // identical semantics either way)
            let mut svc = ClusterService::start(cfg(shards));
            let mut scan = DirectScan::open_mmap(&path, readers, 512, shards, None)
                .expect("open direct mmap scan");
            svc.ingest_direct(&mut scan);
            assert!(scan.take_error().is_none());
            assert_eq!(
                svc.finish().labels(),
                want,
                "{name}: direct mmap diverged at readers={readers} shards={shards}"
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn direct_route_is_bit_identical_on_the_golden_sbm_stream() {
    let g = sbm::generate(&SbmConfig::equal(10, 50, 0.3, 0.002, 1712));
    assert_routing_parity("sbm", &g.edges);
}

#[test]
fn direct_route_is_bit_identical_on_the_golden_lfr_stream() {
    let g = lfr::generate(&LfrConfig::named("lfr-route", 600, 10.0, 0.3, 433));
    assert_routing_parity("lfr", &g.edges);
}

#[test]
fn epoch_seal_counts_are_reader_count_invariant() {
    // Sealing is exact count-based inside CrossLog::append: a batch
    // that overfills the open epoch is split at the boundary. Direct
    // dispatch delivers the same cross subsequence in the same order
    // at any reader count, so the sealed-epoch count — and therefore
    // every epoch boundary — must match the funnel's exactly. A small
    // bounded horizon keeps the epoch length tiny so the stream seals
    // many epochs.
    let g = sbm::generate(&SbmConfig::equal(10, 50, 0.3, 0.002, 1712));
    let path = tmp("seals");
    write_binary_edges_with(&path, &g.edges, 64).expect("write golden binary");
    let mk_cfg = || {
        let mut c = cfg(4);
        c.horizon = CommitHorizon::Edges(256); // epoch_len = 64
        c
    };

    // funnel reference: sealed-epoch count and cross arrival total
    let (want_sealed, want_cross) = {
        let mut svc = ClusterService::start(mk_cfg());
        let handle = svc.handle();
        let mut scanner = ParallelScanner::open(&path, 1, 512).expect("open funnel scan");
        svc.ingest(&mut scanner, 512);
        assert!(scanner.take_error().is_none());
        // stats() folds the router's still-buffered partial cross
        // batch into cross_total, so the arrival total is whole-stream
        // with no compensating flush; finish() then appends that tail
        // to the log, making the sealed-epoch count whole-stream too.
        let before = handle.stats();
        svc.finish();
        let s = handle.stats();
        assert_eq!(
            before.cross_total, s.cross_total,
            "stats() must already count the router's buffered tail"
        );
        (s.epochs_sealed, s.cross_total)
    };
    assert!(want_sealed > 1, "workload too small to seal epochs");

    for readers in [1usize, 2, 4] {
        let mut svc = ClusterService::start(mk_cfg());
        let handle = svc.handle();
        let mut scan =
            DirectScan::open(&path, readers, 512, 4, None).expect("open direct scan");
        svc.ingest_direct(&mut scan);
        assert!(scan.take_error().is_none());
        let s = handle.stats();
        assert_eq!(s.cross_total, want_cross, "readers={readers}");
        assert_eq!(
            s.epochs_sealed, want_sealed,
            "epoch boundaries moved at readers={readers}"
        );
        // the closed form behind the invariance: seals depend only on
        // the arrival count and the epoch length
        assert_eq!(s.epochs_sealed, s.cross_total / s.cross_epoch_len);
        drop(svc);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn direct_ingest_rejects_a_mismatched_shard_count() {
    let g = sbm::generate(&SbmConfig::equal(4, 25, 0.4, 0.01, 9));
    let path = tmp("mismatch");
    write_binary_edges_with(&path, &g.edges, 64).expect("write golden binary");
    let mut scan = DirectScan::open(&path, 2, 512, 2, None).expect("open direct scan");
    let mut svc = ClusterService::start(cfg(4));
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        svc.ingest_direct(&mut scan);
    }));
    assert!(err.is_err(), "shard-count mismatch must fail fast");
    std::fs::remove_file(&path).ok();
}
