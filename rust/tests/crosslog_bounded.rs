//! Memory boundedness of the commit-horizon cross-edge log, end to end:
//! stream a high-cross-fraction SBM through the service with
//! `CommitHorizon::Edges(h)` and assert — via the service's own stats
//! counters — that retained cross-log edges never exceed `h` plus one
//! epoch at any drain point, that commits actually free memory, and
//! that the bounded run's final quality stays within 2% modularity of
//! the unbounded run.

use streamcom::graph::generators::sbm::{self, SbmConfig};
use streamcom::metrics::modularity::modularity;
use streamcom::service::{ClusterService, CommitHorizon, ServiceConfig};

/// Strongly separated SBM over 4 shards: ~3/4 of all edges are
/// cross-shard, so an unbounded log would retain most of the stream.
fn workload() -> streamcom::graph::generators::GeneratedGraph {
    sbm::generate(&SbmConfig::equal(10, 40, 0.4, 0.002, 71))
}

fn service_config(horizon: CommitHorizon) -> ServiceConfig {
    // a *binding* v_max (the paper's regime): the unbounded terminal
    // replay decides all cross edges at end-of-stream volumes, where
    // the threshold rejects most joins, while the bounded run commits
    // decisions made mid-stream when volumes were still under the cap.
    // A commit-horizon simulation over lag/seed variations shows the
    // bounded run's modularity at or above the unbounded run's
    // throughout this regime, so the 2% tolerance has a wide margin
    let mut cfg = ServiceConfig::new(4, 128);
    cfg.chunk_size = 32;
    cfg.drain_every = 128;
    cfg.horizon = horizon;
    cfg
}

#[test]
fn retained_cross_edges_never_exceed_horizon_plus_one_epoch() {
    let g = workload();
    let h = 256u64;
    let mut svc = ClusterService::start(service_config(CommitHorizon::Edges(h)));
    let handle = svc.handle();

    for chunk in g.edges.edges.chunks(200) {
        svc.push_chunk(chunk);
        // quiesce = flush + drain: every epoch behind the horizon has
        // just been committed, so this is exactly where the bound must
        // hold (between drains it can additionally lag by the cadence)
        svc.quiesce();
        let s = handle.stats();
        assert!(
            s.cross_retained <= h + s.cross_epoch_len,
            "retained {} > horizon {h} + epoch {}",
            s.cross_retained,
            s.cross_epoch_len
        );
        assert_eq!(
            s.cross_committed + s.cross_retained,
            s.cross_total,
            "every logged cross edge is either resident or committed"
        );
        // the leader partitions always account for the whole log
        assert_eq!(
            s.per_leader.iter().map(|l| l.retained_bytes).sum::<u64>(),
            s.cross_log_bytes,
        );
        assert_eq!(
            s.per_leader.iter().map(|l| l.freed_bytes).sum::<u64>(),
            s.cross_freed_bytes,
        );
    }

    let s = handle.stats();
    // the workload's cross fraction is ~75%, far above the horizon: the
    // log must actually have committed and freed something
    assert!(
        s.cross_total > 4 * (h + s.cross_epoch_len),
        "workload too small to exercise the bound: cross_total={}",
        s.cross_total
    );
    assert!(s.cross_committed > 0, "nothing was committed");
    assert!(s.epochs_committed > 0, "no epoch was finalized");
    assert!(s.cross_freed_bytes > 0, "commits must free bytes");
    assert!(
        s.cross_log_bytes <= (h + s.cross_epoch_len) * (8 + 16),
        "resident log bytes {} exceed the analytic bound",
        s.cross_log_bytes
    );

    // coverage invariants survive the bounded replay
    let res = svc.finish();
    assert_eq!(res.edges_ingested, g.m() as u64);
    assert_eq!(res.snapshot.edges(), g.m() as u64);
    assert_eq!(res.state().total_volume(), 2 * g.m() as u64);
}

#[test]
fn bounded_horizon_modularity_within_two_percent_of_unbounded() {
    let g = workload();

    let mut unbounded = ClusterService::start(service_config(CommitHorizon::Unbounded));
    unbounded.push_chunk(&g.edges.edges);
    let full = unbounded.finish().snapshot.labels_padded(g.n());

    let mut bounded = ClusterService::start(service_config(CommitHorizon::Edges(256)));
    bounded.push_chunk(&g.edges.edges);
    let capped = bounded.finish().snapshot.labels_padded(g.n());

    let q_full = modularity(g.n(), &g.edges.edges, &full);
    let q_capped = modularity(g.n(), &g.edges.edges, &capped);
    assert!(
        q_full > 0.2,
        "unbounded run must find real structure, got Q={q_full:.4}"
    );
    assert!(
        q_capped >= q_full - 0.02 * q_full.abs(),
        "bounded-horizon modularity {q_capped:.4} fell more than 2% below \
         the unbounded run's {q_full:.4}"
    );
}

#[test]
fn unbounded_service_retains_everything_until_finish() {
    // the control: with the default horizon the log never commits, and
    // the retained count equals the lifetime total — today's (and the
    // batch path's) semantics, unchanged
    let g = workload();
    let mut svc = ClusterService::start(service_config(CommitHorizon::Unbounded));
    let handle = svc.handle();
    svc.push_chunk(&g.edges.edges);
    svc.quiesce();
    let s = handle.stats();
    assert_eq!(s.cross_retained, s.cross_total);
    assert_eq!(s.cross_committed, 0);
    assert_eq!(s.cross_freed_bytes, 0);
    assert_eq!(s.epochs_committed, 0);
    // no frozen records are kept: resident bytes are edges only
    assert_eq!(s.cross_log_bytes, s.cross_retained * 8);
    svc.finish();
}
