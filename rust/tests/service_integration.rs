//! Service semantics end-to-end: snapshot-during-ingest validity,
//! backpressure (block, never drop), incremental-drain accounting
//! (each cross edge replayed exactly once by the snapshot path), a
//! worst-case mailbox-capacity-1 stress run against the unified
//! router, and detection-quality parity with the batch coordinator on
//! the Table 2 parity workload — same workload shape and tolerances as
//! `parallel_parity.rs`.

use streamcom::coordinator::algorithm::cluster_edges;
use streamcom::coordinator::parallel::{run_parallel, ParallelConfig};
use streamcom::graph::generators::sbm::{self, SbmConfig};
use streamcom::metrics::{f1::average_f1_labels, nmi::nmi_labels};
use streamcom::service::{ClusterService, ServiceConfig};

#[test]
fn service_parity_with_sequential_on_table2_workload() {
    // the parallel_parity.rs workload and tolerances, served online
    for (shards, seed) in [(2usize, 101u64), (4, 102), (8, 103)] {
        let g = sbm::generate(&SbmConfig::equal(12, 60, 0.3, 0.002, seed));
        let truth = g.truth.to_labels(g.n());
        let v_max = 128;

        let seq = cluster_edges(g.n(), &g.edges.edges, v_max);

        let mut svc = ClusterService::start(ServiceConfig::new(shards, v_max));
        svc.push_chunk(&g.edges.edges);
        let res = svc.finish();
        let svc_labels = res.snapshot.labels_padded(g.n());

        let (nmi_s, nmi_v) = (nmi_labels(&seq, &truth), nmi_labels(&svc_labels, &truth));
        let (f1_s, f1_v) = (
            average_f1_labels(&seq, &truth),
            average_f1_labels(&svc_labels, &truth),
        );
        assert!(
            nmi_v >= nmi_s - 0.15,
            "shards={shards}: NMI {nmi_v:.3} vs sequential {nmi_s:.3}"
        );
        assert!(
            f1_v >= f1_s * 0.7,
            "shards={shards}: F1 {f1_v:.3} vs sequential {f1_s:.3}"
        );
        // every edge processed exactly once
        assert_eq!(res.snapshot.local_edges + res.snapshot.cross_edges, g.m() as u64);
        assert_eq!(res.snapshot.edges(), g.m() as u64);
    }
}

#[test]
fn service_final_partition_equals_batch_coordinator() {
    let g = sbm::generate(&SbmConfig::equal(12, 60, 0.3, 0.002, 104));
    let v_max = 128;
    let shards = 4;

    let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(shards, v_max));
    let par_labels = par.labels();

    let mut svc = ClusterService::start(ServiceConfig::new(shards, v_max));
    svc.push_chunk(&g.edges.edges);
    let svc_labels = svc.finish().snapshot.labels_padded(g.n());

    assert_eq!(
        svc_labels, par_labels,
        "online service must replay to the batch coordinator's partition"
    );
}

#[test]
fn snapshots_answer_queries_mid_stream() {
    let g = sbm::generate(&SbmConfig::equal(10, 50, 0.35, 0.003, 42));
    let mut cfg = ServiceConfig::new(4, 128);
    cfg.chunk_size = 256;
    let mut svc = ClusterService::start(cfg);
    let handle = svc.handle();

    let quarter = g.m() / 4;
    let mut last_edges = 0u64;
    for q in 0..4 {
        let lo = q * quarter;
        let hi = if q == 3 { g.m() } else { (q + 1) * quarter };
        svc.push_chunk(&g.edges.edges[lo..hi]);
        let snap = svc.quiesce();

        // each snapshot covers exactly the pushed prefix...
        assert_eq!(snap.edges(), hi as u64, "quarter {q}");
        // ...is a valid partition (stream-end invariants mid-stream)...
        assert_eq!(snap.state().total_volume(), 2 * snap.edges(), "quarter {q}");
        let n = snap.state().n();
        assert!(snap.labels().iter().all(|&l| (l as usize) < n), "quarter {q}");
        // ...and is monotonically fresher through the shared handle
        let seen = handle.snapshot().edges();
        assert!(seen >= last_edges, "quarter {q}: snapshot went backwards");
        last_edges = seen;

        // point lookups agree with the snapshot's own labels
        let labels = snap.labels();
        for probe in [0usize, n / 2, n.saturating_sub(1)] {
            assert_eq!(snap.community_of(probe as u32), labels[probe], "quarter {q}");
        }
    }

    let res = svc.finish();
    assert_eq!(res.snapshot.edges(), g.m() as u64);
}

#[test]
fn tiny_mailboxes_backpressure_without_losing_edges() {
    // depth-1 mailboxes and tiny chunks force constant blocking on the
    // push path; the stream must still be processed exactly once
    let g = sbm::generate(&SbmConfig::equal(8, 40, 0.3, 0.01, 7));
    let mut cfg = ServiceConfig::new(4, 64);
    cfg.mailbox_depth = 1;
    cfg.chunk_size = 16;
    let mut svc = ClusterService::start(cfg);
    let handle = svc.handle();
    svc.push_chunk(&g.edges.edges);
    let stats = handle.stats();
    let res = svc.finish();

    assert_eq!(res.edges_ingested, g.m() as u64);
    assert_eq!(res.snapshot.edges(), g.m() as u64);
    assert_eq!(res.state().total_volume(), 2 * g.m() as u64);
    // the bounded mailbox never exceeded its depth
    for &peak in &stats.queue_peaks {
        assert!(peak <= 1, "peaks={:?}", stats.queue_peaks);
    }
}

#[test]
fn drain_work_is_proportional_to_new_cross_edges() {
    // the acceptance criterion for the incremental leader: across any
    // number of drains, the snapshot path replays every cross edge
    // exactly once — per-drain work is O(cross since last drain), not
    // O(all cross so far)
    let g = sbm::generate(&SbmConfig::equal(10, 50, 0.3, 0.002, 57));
    let drain_every = 500u64;
    let mut cfg = ServiceConfig::new(4, 64);
    cfg.chunk_size = 64;
    cfg.drain_every = drain_every;
    let mut svc = ClusterService::start(cfg);
    let handle = svc.handle();

    // the drain clock is batch-granular: stream in batches no larger
    // than the cadence so automatic drains actually fire mid-stream
    // (a single giant batch would legitimately drain once, at its end)
    for chunk in g.edges.edges.chunks(250) {
        svc.push_chunk(chunk);
    }
    svc.quiesce();
    let s = handle.stats();

    let expected_drains = g.m() as u64 / drain_every;
    assert!(
        s.drains > expected_drains,
        "expected > {expected_drains} automatic drains + quiesce, saw {}",
        s.drains
    );
    // everything buffered has been integrated...
    assert_eq!(s.cross_pending, 0);
    assert_eq!(s.cross_drained, s.cross_total);
    // ...and the total replay work equals the number of distinct cross
    // edges: the old full-buffer drain would have replayed
    // ~drains × cross/2 edges here
    assert_eq!(
        s.cross_replayed_total, s.cross_drained,
        "snapshot drains must replay each cross edge exactly once"
    );
    // no single drain can replay more than one cadence interval's worth
    assert!(
        s.cross_replayed_last_drain <= drain_every,
        "last drain replayed {} > cadence {drain_every}",
        s.cross_replayed_last_drain
    );

    // and the mid-stream drains must not have perturbed the final
    // partition: finish runs the terminal full replay
    let res = svc.finish();
    let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(4, 64));
    assert_eq!(res.snapshot.labels_padded(g.n()), par.labels());
}

#[test]
fn unified_router_survives_capacity_one_mailboxes() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    // worst-case backpressure: every dispatch is a 1-edge chunk into a
    // depth-1 mailbox, with frequent automatic drains and two query
    // threads forcing extra drains concurrently. The run must not
    // deadlock, must not drop edges, and must keep the conservation
    // invariants — and the final partition must still be bit-identical
    // to the batch coordinator.
    let g = sbm::generate(&SbmConfig::equal(6, 30, 0.35, 0.01, 61));
    let mut cfg = ServiceConfig::new(4, 64);
    cfg.mailbox_depth = 1;
    cfg.chunk_size = 1;
    cfg.drain_every = 17;
    let mut svc = ClusterService::start(cfg);
    let handle = svc.handle();

    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..2)
        .map(|_| {
            let handle = handle.clone();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut snapshots = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let snap = handle.refresh();
                    // every mid-stream view is a valid partition
                    assert_eq!(snap.state().total_volume(), 2 * snap.edges());
                    let _ = handle.stats();
                    snapshots += 1;
                }
                snapshots
            })
        })
        .collect();

    svc.push_chunk(&g.edges.edges);
    let snap = svc.quiesce();
    assert_eq!(snap.edges(), g.m() as u64, "quiesce must cover the pushed prefix");

    stop.store(true, Ordering::Relaxed);
    for r in readers {
        let snapshots = r.join().expect("reader panicked");
        assert!(snapshots > 0);
    }

    let stats = handle.stats();
    for &peak in &stats.queue_peaks {
        assert!(peak <= 1, "depth-1 mailbox exceeded: {:?}", stats.queue_peaks);
    }
    assert_eq!(stats.cross_replayed_total, stats.cross_drained);

    let res = svc.finish();
    assert_eq!(res.edges_ingested, g.m() as u64, "no edge may be dropped");
    assert_eq!(res.snapshot.edges(), g.m() as u64);
    assert_eq!(res.state().total_volume(), 2 * g.m() as u64);

    let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(4, 64));
    assert_eq!(res.snapshot.labels_padded(g.n()), par.labels());
}

#[test]
fn ingest_spine_recycles_chunks_with_zero_steady_state_allocations() {
    // the zero-allocation acceptance criterion, made observable via
    // the pool counters: boot prewarms the shelf to the in-flight
    // bound — per shard: the pending buffer, mailbox_depth queued
    // chunks, one in the worker's hands, one in transit during the
    // swap — so checkout can never find it empty. There is no warm-up
    // ramp left: misses must be exactly zero while hits keep growing
    // with the stream. Any regression that reintroduces a per-chunk
    // allocation (or breaks the prewarm) shows up as misses > 0.
    let g = sbm::generate(&SbmConfig::equal(12, 60, 0.3, 0.002, 211));
    let shards = 2usize;
    let depth = 2usize;
    let mut cfg = ServiceConfig::new(shards, 64);
    cfg.chunk_size = 32; // many dispatch cycles
    cfg.mailbox_depth = depth;
    cfg.drain_every = u64::MAX;
    let mut svc = ClusterService::start(cfg);
    let handle = svc.handle();
    for chunk in g.edges.edges.chunks(256) {
        svc.push_chunk(chunk);
    }
    svc.quiesce();
    let s = handle.stats();

    let in_flight_ceiling = (shards * (depth + 3)) as u64;
    assert_eq!(
        s.pool.misses, 0,
        "the prewarmed pool must serve every checkout from the shelf \
         ({} hits, {} dispatched)",
        s.pool.hits, s.chunks_dispatched
    );
    assert!(
        s.chunks_dispatched > 4 * in_flight_ceiling,
        "workload too small to exercise recycling: {} chunks",
        s.chunks_dispatched
    );
    assert!(
        s.pool.hits >= s.chunks_dispatched - s.pool.misses,
        "hits {} must cover nearly every dispatch ({} chunks, {} misses)",
        s.pool.hits,
        s.chunks_dispatched,
        s.pool.misses
    );
    assert!(s.pool.recycled_bytes > 0);
    // router-side RMW amortization: one dispatched-add per chunk, one
    // ingested-add per batch — far below one per edge
    assert!(s.chunks_dispatched < g.m() as u64 / 4);

    // pool recycling must not lose or duplicate a chunk: every pushed
    // edge is processed exactly once and the final partition is the
    // batch coordinator's
    let res = svc.finish();
    assert_eq!(res.edges_ingested, g.m() as u64);
    assert_eq!(res.snapshot.edges(), g.m() as u64);
    assert_eq!(res.state().total_volume(), 2 * g.m() as u64);
    let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(shards, 64));
    assert_eq!(res.snapshot.labels_padded(g.n()), par.labels());
}

#[test]
fn pool_counters_flow_through_stats_endpoint() {
    // hits + misses covers every checkout (initial pending buffers +
    // one per dispatch), and recycled bytes only ever grow
    let g = sbm::generate(&SbmConfig::equal(6, 30, 0.4, 0.01, 212));
    let mut cfg = ServiceConfig::new(3, 64);
    cfg.chunk_size = 16;
    let mut svc = ClusterService::start(cfg);
    let handle = svc.handle();
    let before = handle.stats();
    svc.push_chunk(&g.edges.edges);
    svc.quiesce();
    let after = handle.stats();
    assert_eq!(
        after.pool.hits + after.pool.misses,
        // 3 initial pending checkouts + one replacement per dispatch
        3 + after.chunks_dispatched,
        "every checkout must be a hit or a miss"
    );
    assert!(after.pool.recycled_bytes >= before.pool.recycled_bytes);
    assert!(after.chunks_dispatched > before.chunks_dispatched);
    svc.finish();
}

#[test]
fn stats_endpoint_tracks_ingest() {
    let g = sbm::generate(&SbmConfig::equal(6, 30, 0.4, 0.01, 3));
    let mut svc = ClusterService::start(ServiceConfig::new(2, 64));
    let handle = svc.handle();
    svc.push_chunk(&g.edges.edges);
    svc.quiesce();

    let s = handle.stats();
    assert_eq!(s.edges_ingested, g.m() as u64);
    assert_eq!(s.snapshot_edges, g.m() as u64);
    assert_eq!(s.queue_depths.len(), 2);
    assert_eq!(s.queue_peaks.len(), 2);
    assert!(s.edges_per_sec > 0.0);
    assert!(s.memory_bytes >= 16 * s.nodes, "sketch below 16 B/node");
    svc.finish();
}
