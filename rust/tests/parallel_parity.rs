//! Detection-quality parity between the sequential algorithm and the
//! sharded leader/worker coordinator (DESIGN.md: deferred cross-edge
//! resolution must not cost detection quality on SBM workloads).

use streamcom::coordinator::algorithm::cluster_edges;
use streamcom::coordinator::parallel::{run_parallel, ParallelConfig};
use streamcom::graph::generators::sbm::{self, SbmConfig};
use streamcom::metrics::{f1::average_f1_labels, nmi::nmi_labels};

fn parity_case(shards: usize, seed: u64) {
    let g = sbm::generate(&SbmConfig::equal(12, 60, 0.3, 0.002, seed));
    let truth = g.truth.to_labels(g.n());
    let v_max = 128;

    let seq = cluster_edges(g.n(), &g.edges.edges, v_max);
    let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(shards, v_max));
    let par_labels = par.labels();

    let (nmi_s, nmi_p) = (nmi_labels(&seq, &truth), nmi_labels(&par_labels, &truth));
    let (f1_s, f1_p) = (
        average_f1_labels(&seq, &truth),
        average_f1_labels(&par_labels, &truth),
    );
    assert!(
        nmi_p >= nmi_s - 0.15,
        "shards={shards}: NMI {nmi_p:.3} vs sequential {nmi_s:.3}"
    );
    assert!(
        f1_p >= f1_s * 0.7,
        "shards={shards}: F1 {f1_p:.3} vs sequential {f1_s:.3}"
    );
    // every edge must be processed exactly once
    assert_eq!(par.local_edges + par.cross_edges, g.m() as u64);
}

#[test]
fn parity_two_shards() {
    parity_case(2, 101);
}

#[test]
fn parity_four_shards() {
    parity_case(4, 102);
}

#[test]
fn parity_eight_shards() {
    parity_case(8, 103);
}

#[test]
fn cross_edge_fraction_grows_with_shards() {
    let g = sbm::generate(&SbmConfig::equal(8, 50, 0.3, 0.01, 7));
    let frac = |shards: usize| {
        let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(shards, 64));
        par.cross_edges as f64 / g.m() as f64
    };
    let f2 = frac(2);
    let f8 = frac(8);
    assert!(f2 < f8, "cross fraction {f2} !< {f8}");
    // expectation: 1 - 1/s
    assert!((f2 - 0.5).abs() < 0.1, "f2={f2}");
    assert!((f8 - 0.875).abs() < 0.08, "f8={f8}");
}

#[test]
fn parallel_is_deterministic_given_config() {
    let g = sbm::generate(&SbmConfig::equal(6, 40, 0.3, 0.01, 11));
    let cfg = ParallelConfig::new(4, 64);
    let a = run_parallel(g.n(), &g.edges.edges, &cfg);
    let b = run_parallel(g.n(), &g.edges.edges, &cfg);
    assert_eq!(a.labels(), b.labels());
}
