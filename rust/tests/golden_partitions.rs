//! Golden-partition regression tests.
//!
//! `rust/tests/golden/` holds committed fixed-seed edge streams
//! (SBM-shaped and LFR-shaped) together with the expected label vectors
//! for the sequential run and the sharded batch run. Any change to the
//! routing core, the merge, the replay order, or the decision rule that
//! silently alters a partition fails these tests loudly, with a
//! node-by-node diff.
//!
//! The streams are data files, not generator calls, so the goldens are
//! independent of the in-repo generators and RNG. To regenerate the
//! expected labels after an *intentional* semantics change, run with
//! `GOLDEN_REGEN=1` and review the resulting diff:
//!
//! ```text
//! GOLDEN_REGEN=1 cargo test --test golden_partitions
//! ```

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use streamcom::coordinator::algorithm::cluster_edges;
use streamcom::coordinator::parallel::{run_parallel, ParallelConfig};
use streamcom::graph::edge::Edge;
use streamcom::metrics::modularity::modularity;
use streamcom::service::{ClusterService, CommitHorizon, ServiceConfig};

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

/// A committed golden stream: node count, `v_max`, shard count for the
/// sharded variant, and the edges in arrival order.
struct GoldenStream {
    n: usize,
    v_max: u64,
    shards: usize,
    edges: Vec<Edge>,
}

fn read_stream(stem: &str) -> GoldenStream {
    let path = golden_dir().join(format!("{stem}.edges"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()));
    let mut lines = text.lines().filter(|l| !l.trim_start().starts_with('#'));
    let header = lines.next().expect("missing golden header line");
    let mut parts = header.split_whitespace();
    let n: usize = parts.next().expect("header n").parse().expect("header n");
    let v_max: u64 = parts.next().expect("header v_max").parse().expect("header v_max");
    let shards: usize = parts.next().expect("header shards").parse().expect("header shards");
    let edges: Vec<Edge> = lines
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let mut it = l.split_whitespace();
            let u: u32 = it.next().expect("edge u").parse().expect("edge u");
            let v: u32 = it.next().expect("edge v").parse().expect("edge v");
            Edge::new(u, v)
        })
        .collect();
    assert!(n > 0 && !edges.is_empty(), "degenerate golden stream {stem}");
    GoldenStream { n, v_max, shards, edges }
}

fn labels_path(stem: &str, which: &str) -> PathBuf {
    golden_dir().join(format!("{stem}.{which}.labels"))
}

fn read_labels(stem: &str, which: &str) -> Vec<u32> {
    let path = labels_path(stem, which);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()))
        .lines()
        .filter(|l| !l.trim_start().starts_with('#') && !l.trim().is_empty())
        .map(|l| l.trim().parse().expect("label"))
        .collect()
}

fn write_labels(stem: &str, which: &str, labels: &[u32]) {
    let path = labels_path(stem, which);
    let mut out = String::with_capacity(labels.len() * 4);
    for &l in labels {
        let _ = writeln!(out, "{l}");
    }
    std::fs::write(&path, out).unwrap_or_else(|e| panic!("cannot write {}: {e}", path.display()));
    eprintln!("golden: regenerated {}", path.display());
}

/// Diff-printing assertion: on mismatch, report how many labels differ
/// and show the first divergent nodes side by side, so a failure reads
/// as a partition diff instead of a wall of vector debug output.
fn assert_labels_match(case: &str, got: &[u32], want: &[u32]) {
    if got == want {
        return;
    }
    let mut msg = format!("golden mismatch [{case}]: ");
    if got.len() != want.len() {
        let _ = writeln!(msg, "length {} != expected {}", got.len(), want.len());
    }
    let overlap = got.len().min(want.len());
    let diffs: Vec<usize> = (0..overlap).filter(|&i| got[i] != want[i]).collect();
    let _ = writeln!(msg, "{} of {} labels differ", diffs.len(), overlap);
    let _ = writeln!(msg, "  node | expected | got");
    for &i in diffs.iter().take(16) {
        let _ = writeln!(msg, "{i:>6} | {:>8} | {:>6}", want[i], got[i]);
    }
    if diffs.len() > 16 {
        let _ = writeln!(msg, "   ... | ({} more)", diffs.len() - 16);
    }
    let _ = write!(
        msg,
        "if this change of partition is intentional, regenerate with \
         GOLDEN_REGEN=1 cargo test --test golden_partitions"
    );
    panic!("{msg}");
}

fn pad(mut labels: Vec<u32>, n: usize) -> Vec<u32> {
    while labels.len() < n {
        labels.push(labels.len() as u32);
    }
    labels
}

/// One golden case: sequential and sharded-batch labels must match the
/// committed vectors, and both service modes (batch preset; frequent
/// incremental drains) must reproduce the sharded-batch labels
/// bit-identically.
fn check_case(stem: &str) {
    let gs = read_stream(stem);
    let seq = pad(cluster_edges(gs.n, &gs.edges, gs.v_max), gs.n);
    let par = pad(
        run_parallel(gs.n, &gs.edges, &ParallelConfig::new(gs.shards, gs.v_max)).labels(),
        gs.n,
    );

    if std::env::var_os("GOLDEN_REGEN").is_some() {
        write_labels(stem, "seq", &seq);
        write_labels(stem, &format!("par{}", gs.shards), &par);
        return;
    }

    assert_labels_match(
        &format!("{stem}: sequential"),
        &seq,
        &read_labels(stem, "seq"),
    );
    assert_labels_match(
        &format!("{stem}: batch shards={}", gs.shards),
        &par,
        &read_labels(stem, &format!("par{}", gs.shards)),
    );

    // the service IS the batch path: bit-identical in the batch preset…
    let mut svc = ClusterService::start(ServiceConfig::batch(gs.shards, gs.v_max));
    svc.push_chunk(&gs.edges);
    let batch_labels = svc.finish().snapshot.labels_padded(gs.n);
    assert_labels_match(&format!("{stem}: service batch preset"), &batch_labels, &par);

    // …and under frequent incremental drains, because finish always
    // runs the terminal full replay
    let mut cfg = ServiceConfig::new(gs.shards, gs.v_max);
    cfg.drain_every = 97;
    cfg.chunk_size = 64;
    let mut svc = ClusterService::start(cfg);
    svc.push_chunk(&gs.edges);
    let drained_labels = svc.finish().snapshot.labels_padded(gs.n);
    assert_labels_match(
        &format!("{stem}: service with incremental drains"),
        &drained_labels,
        &par,
    );

    // a commit horizon at least as long as the stream can never commit
    // an epoch, so it must stay bit-identical to the unbounded run —
    // Unbounded and "horizon ≥ stream length" are the same semantics
    let mut cfg = ServiceConfig::new(gs.shards, gs.v_max);
    cfg.drain_every = 97;
    cfg.chunk_size = 64;
    cfg.horizon = CommitHorizon::Edges(gs.edges.len() as u64);
    let mut svc = ClusterService::start(cfg);
    svc.push_chunk(&gs.edges);
    let horizon_labels = svc.finish().snapshot.labels_padded(gs.n);
    assert_labels_match(
        &format!("{stem}: service, horizon ≥ stream length"),
        &horizon_labels,
        &par,
    );

    // a *bounded* horizon frees old cross epochs and finalizes their
    // decisions; the partition may drift from batch, but quality must
    // stay within 2% modularity of the unbounded run on these streams
    let mut cfg = ServiceConfig::new(gs.shards, gs.v_max);
    cfg.drain_every = 61;
    cfg.chunk_size = 64;
    cfg.horizon = CommitHorizon::Edges((gs.edges.len() / 4).max(16) as u64);
    let mut svc = ClusterService::start(cfg);
    svc.push_chunk(&gs.edges);
    let bounded_labels = svc.finish().snapshot.labels_padded(gs.n);
    let q_full = modularity(gs.n, &gs.edges, &par);
    let q_bounded = modularity(gs.n, &gs.edges, &bounded_labels);
    assert!(
        q_bounded >= q_full - 0.02 * q_full.abs(),
        "{stem}: bounded-horizon modularity {q_bounded:.4} fell more than \
         2% below the unbounded run's {q_full:.4}"
    );
}

#[test]
fn golden_sbm_stream_partitions_are_stable() {
    check_case("sbm_k6_s30");
}

#[test]
fn golden_lfr_stream_partitions_are_stable() {
    check_case("lfr_mu015");
}

#[test]
fn dynamic_event_mode_matches_batch_mode_on_golden_streams() {
    // the CLI's event mode now batches consecutive inserts through
    // `DynamicClusterer::insert_batch` (the same chunk spine as the
    // batch path); an insert-only event stream must therefore stay
    // bit-identical to the sequential batch run — whatever the batch
    // boundaries — and to per-event application
    use streamcom::coordinator::algorithm::StrConfig;
    use streamcom::coordinator::dynamic::{DynamicClusterer, Event};
    for stem in ["sbm_k6_s30", "lfr_mu015"] {
        let gs = read_stream(stem);
        let seq = pad(cluster_edges(gs.n, &gs.edges, gs.v_max), gs.n);

        let mut batched = DynamicClusterer::new(0, StrConfig::new(gs.v_max));
        for chunk in gs.edges.chunks(113) {
            batched.insert_batch(chunk);
        }
        assert_labels_match(
            &format!("{stem}: event mode (batched inserts) vs sequential batch"),
            &pad(batched.labels(), gs.n),
            &seq,
        );

        let mut single = DynamicClusterer::new(0, StrConfig::new(gs.v_max));
        for &e in &gs.edges {
            single.apply(Event::Insert(e)).unwrap();
        }
        assert_labels_match(
            &format!("{stem}: per-event inserts vs sequential batch"),
            &pad(single.labels(), gs.n),
            &seq,
        );
        assert_eq!(batched.live_edges(), single.live_edges(), "{stem}");
    }
}

#[test]
fn golden_diff_helper_reports_node_level_diffs() {
    // the helper itself is part of the contract: a mismatch must name
    // the diverging nodes
    let err = std::panic::catch_unwind(|| {
        assert_labels_match("selftest", &[0, 1, 2, 2], &[0, 1, 1, 2]);
    })
    .expect_err("mismatch must panic");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()).unwrap());
    assert!(msg.contains("1 of 4 labels differ"), "{msg}");
    assert!(msg.contains("GOLDEN_REGEN"), "{msg}");
}
