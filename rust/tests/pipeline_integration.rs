//! End-to-end integration across the stream substrate + coordinator +
//! metrics: file → chunked pipeline → clustering → scoring, and the
//! multi-parameter sweep → selection path.

use streamcom::bench::workloads;
use streamcom::coordinator::algorithm::{StrConfig, StreamingClusterer};
use streamcom::coordinator::selection::{select, NativeEngine, SelectionRule};
use streamcom::coordinator::sweep::MultiSweep;
use streamcom::graph::generators::presets::SNAP_PRESETS;
use streamcom::graph::generators::sbm::{self, SbmConfig};
use streamcom::graph::io;
use streamcom::metrics::{f1, modularity, nmi};
use streamcom::stream::chunk::{ChunkConfig, ChunkStream};
use streamcom::stream::source::{BinaryFileSource, TextFileSource};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("sc_it_{}_{name}", std::process::id()))
}

#[test]
fn file_to_clustering_to_scores_binary() {
    let g = sbm::generate(&SbmConfig::equal(8, 40, 0.35, 0.005, 42));
    let path = tmp("pipe.bin");
    io::write_binary_edges(&path, &g.edges).unwrap();

    let source = BinaryFileSource::open(&path).unwrap();
    let stream = ChunkStream::spawn(source, ChunkConfig { chunk_size: 1000, depth: 3 });
    let mut c = StreamingClusterer::new(g.n(), StrConfig::new(64));
    while let Some(chunk) = stream.next_chunk() {
        c.process_chunk(&chunk);
    }
    assert_eq!(c.state.edges_processed, g.m() as u64);

    let labels = c.labels();
    let truth = g.truth.to_labels(g.n());
    let f1 = f1::average_f1_labels(&labels, &truth);
    let nmi = nmi::nmi_labels(&labels, &truth);
    let q = modularity::modularity(g.n(), &g.edges.edges, &labels);
    assert!(f1 > 0.3, "f1={f1}");
    assert!(nmi > 0.5, "nmi={nmi}");
    assert!(q > 0.2, "q={q}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn file_to_clustering_text_roundtrip_matches_memory_run() {
    let g = sbm::generate(&SbmConfig::equal(5, 30, 0.4, 0.01, 17));
    let path = tmp("pipe.txt");
    io::write_text_edges(&path, &g.edges).unwrap();

    let mut from_file = StreamingClusterer::new(g.n(), StrConfig::new(32));
    let mut source = TextFileSource::open(&path).unwrap();
    from_file.run(&mut source, 512);

    let mut from_mem = StreamingClusterer::new(g.n(), StrConfig::new(32));
    from_mem.process_chunk(&g.edges.edges);

    assert_eq!(from_file.labels(), from_mem.labels());
    std::fs::remove_file(&path).ok();
}

#[test]
fn sweep_selection_end_to_end_beats_fixed_extremes() {
    let g = sbm::generate(&SbmConfig::equal(10, 40, 0.35, 0.004, 99));
    let truth = g.truth.to_labels(g.n());
    // the production ladder anchors at the average degree (volumes
    // scale with degree — see bench::table1::select_v_max)
    let avg_deg = (2 * g.m() / g.n()).max(4) as u64;
    let ladder = MultiSweep::geometric_ladder(avg_deg, 8);
    let mut sweep = MultiSweep::new(g.n(), ladder.clone());
    sweep.process_chunk(&g.edges.edges);
    let (winner, _) = select(&sweep, &mut NativeEngine, SelectionRule::DensityScore);

    let f1_of = |labels: &Vec<u32>| f1::average_f1_labels(labels, &truth);
    let f1_winner = f1_of(&sweep.labels(winner));
    let f1_first = f1_of(&sweep.labels(0));
    let f1_last = f1_of(&sweep.labels(ladder.len() - 1));
    // the sketch-only selection must not pick something much worse than
    // either extreme of its own ladder
    assert!(
        f1_winner >= f1_first.max(f1_last) * 0.8,
        "winner {f1_winner} vs extremes {f1_first}/{f1_last}"
    );
}

#[test]
fn workload_presets_have_expected_shape() {
    // the two smallest presets at tiny scale: ground truth present,
    // mixing ordered as configured
    let a = workloads::load_preset(&SNAP_PRESETS[0], 0.01, false);
    assert!(a.truth.len() > 2);
    let intra_frac = |g: &streamcom::graph::generators::GeneratedGraph| {
        let t = g.truth.to_labels(g.n());
        g.edges
            .edges
            .iter()
            .filter(|e| t[e.u as usize] == t[e.v as usize])
            .count() as f64
            / g.m() as f64
    };
    let fa = intra_frac(&a);
    let f = workloads::load_preset(&SNAP_PRESETS[5], 0.001, false);
    let ff = intra_frac(&f);
    assert!(
        fa > ff,
        "amazon-s intra {fa} should exceed friendster-s intra {ff}"
    );
}

#[test]
fn parallel_pipeline_with_backpressure_processes_everything() {
    use streamcom::coordinator::parallel::{run_parallel, ParallelConfig};
    let g = sbm::generate(&SbmConfig::equal(8, 50, 0.3, 0.01, 3));
    let mut cfg = ParallelConfig::new(4, 64);
    cfg.queue_depth = 2; // force backpressure
    cfg.chunk_size = 64;
    let res = run_parallel(g.n(), &g.edges.edges, &cfg);
    assert_eq!(res.state.edges_processed, g.m() as u64);
    assert_eq!(res.state.total_volume(), 2 * g.m() as u64);
}
