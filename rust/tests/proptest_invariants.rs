//! Property-based invariant tests over the coordinator, using the
//! from-scratch shrinker harness in `util::proptest`.
//!
//! Invariants pinned here are the ones the paper's correctness rests on:
//! volume conservation (Σ v_k = 2t), label validity, sweep/single-run
//! equivalence, order-independence of the sketch *totals*, and the
//! dynamic extension's reversibility.

use streamcom::coordinator::algorithm::{cluster_edges, StrConfig, StreamingClusterer};
use streamcom::coordinator::dynamic::{DynamicClusterer, Event};
use streamcom::coordinator::sweep::MultiSweep;
use streamcom::graph::edge::Edge;
use streamcom::util::proptest::{property, CaseResult};
use streamcom::util::rng::Xoshiro256;

/// Random multigraph edge stream over `size` nodes.
fn random_stream(rng: &mut Xoshiro256, size: usize) -> (usize, Vec<Edge>) {
    let n = size.max(2);
    let m = size * 4;
    let edges = (0..m)
        .map(|_| {
            let u = rng.range(0, n) as u32;
            let mut v = rng.range(0, n) as u32;
            if u == v {
                v = (v + 1) % n as u32;
            }
            Edge::new(u, v)
        })
        .collect();
    (n, edges)
}

fn prop_assert(cond: bool, msg: String) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg)
    }
}

#[test]
fn volume_conservation_holds_for_any_stream_and_vmax() {
    property("volume conservation", 60, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let v_max = 1 + rng.next_below(1000);
        let mut c = StreamingClusterer::new(n, StrConfig::new(v_max));
        for (t, &e) in edges.iter().enumerate() {
            c.process_edge(e);
            if c.state.total_volume() != 2 * (t as u64 + 1) {
                return Err(format!(
                    "Σv = {} ≠ {} at t={t} (v_max={v_max})",
                    c.state.total_volume(),
                    2 * (t + 1)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn labels_are_always_valid_node_ids() {
    property("label validity", 60, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let labels = cluster_edges(n, &edges, 1 + rng.next_below(500));
        prop_assert(
            labels.iter().all(|&l| (l as usize) < n),
            format!("label out of range in {labels:?}"),
        )
    });
}

#[test]
fn community_members_share_label_transitively() {
    // a community label must itself carry that label or be a node whose
    // community id equals the label (community ids are node ids)
    property("label closure", 40, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let labels = cluster_edges(n, &edges, 1 + rng.next_below(200));
        // every label must be used by at least its own node or belong to
        // a nonempty class
        let mut class_count = vec![0usize; n];
        for &l in &labels {
            class_count[l as usize] += 1;
        }
        prop_assert(
            labels.iter().all(|&l| class_count[l as usize] > 0),
            "empty community referenced".into(),
        )
    });
}

#[test]
fn sweep_equals_individual_runs_for_every_ladder() {
    property("sweep/single equivalence", 25, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let base = 1 + rng.next_below(16);
        let ladder = MultiSweep::geometric_ladder(base, 4);
        let mut sweep = MultiSweep::new(n, ladder.clone());
        sweep.process_chunk(&edges);
        for (a, &vm) in ladder.iter().enumerate() {
            let single = cluster_edges(n, &edges, vm);
            if sweep.labels(a) != single {
                return Err(format!("sweep row {a} (v_max={vm}) diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn degrees_match_stream_counts_regardless_of_order() {
    property("degree totals order-independent", 30, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let mut shuffled = edges.clone();
        rng.shuffle(&mut shuffled);
        let mut a = StreamingClusterer::new(n, StrConfig::new(64));
        let mut b = StreamingClusterer::new(n, StrConfig::new(64));
        a.process_chunk(&edges);
        b.process_chunk(&shuffled);
        prop_assert(
            a.state.degree == b.state.degree,
            "degree tables differ under reordering".into(),
        )
    });
}

#[test]
fn insert_delete_roundtrip_restores_sketch_totals() {
    property("dynamic reversibility", 30, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let mut d = DynamicClusterer::new(n, StrConfig::new(32));
        for &e in &edges {
            d.apply(Event::Insert(e)).map_err(|e| format!("{e:?}"))?;
        }
        // delete in random order
        let mut order = edges.clone();
        rng.shuffle(&mut order);
        for &e in &order {
            d.apply(Event::Delete(e)).map_err(|e| format!("{e:?}"))?;
        }
        if d.state().total_volume() != 0 {
            return Err(format!("residual volume {}", d.state().total_volume()));
        }
        prop_assert(
            d.state().degree.iter().all(|&x| x == 0),
            "residual degree after full deletion".into(),
        )
    });
}

#[test]
fn threshold_rejection_monotone_in_vmax() {
    // a larger v_max can only accept a superset of joins *on the same
    // prefix-free first decision*; globally we check the weaker but
    // stable invariant: community count is non-increasing from the
    // smallest to the largest v_max on SBM-like streams
    property("community count trend", 20, |rng, size| {
        use streamcom::graph::generators::sbm::{self, SbmConfig};
        let k = 2 + size / 40;
        let g = sbm::generate(&SbmConfig::equal(k, 20, 0.4, 0.02, rng.next_u64()));
        let small = cluster_edges(g.n(), &g.edges.edges, 2);
        let large = cluster_edges(g.n(), &g.edges.edges, 1_000_000);
        let count = |labels: &[u32]| {
            let mut c = vec![false; labels.len()];
            for &l in labels {
                c[l as usize] = true;
            }
            c.iter().filter(|&&x| x).count()
        };
        prop_assert(
            count(&small) >= count(&large),
            format!("count(v=2)={} < count(v=∞)={}", count(&small), count(&large)),
        )
    });
}

#[test]
fn shard_of_distributes_uniformly() {
    // the service's scaling story rests on balanced shards: for any
    // shard count, hashing a dense id range must land within ±30% of
    // the uniform share on every shard
    use streamcom::stream::shard::shard_of;
    property("shard uniformity", 25, |rng, size| {
        let shards = 2 + rng.next_below(14) as usize;
        let n = 4_000 + size * 50;
        let mut counts = vec![0usize; shards];
        for node in 0..n {
            counts[shard_of(node as u32, shards)] += 1;
        }
        let expect = n as f64 / shards as f64;
        for (s, &c) in counts.iter().enumerate() {
            if (c as f64) < expect * 0.7 || (c as f64) > expect * 1.3 {
                return Err(format!(
                    "shard {s}/{shards}: {c} nodes vs uniform {expect:.0} (n={n})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn route_is_consistent_with_shard_of() {
    use streamcom::stream::shard::{route, shard_of, Route};
    property("route/shard_of consistency", 40, |rng, size| {
        let shards = 1 + rng.next_below(16) as usize;
        let (_, edges) = random_stream(rng, size);
        for e in edges {
            match route(e, shards) {
                Route::Local(s) => {
                    if shard_of(e.u, shards) != s || shard_of(e.v, shards) != s {
                        return Err(format!("{e:?} routed Local({s}) across shards"));
                    }
                }
                Route::Cross => {
                    if shard_of(e.u, shards) == shard_of(e.v, shards) {
                        return Err(format!("{e:?} routed Cross within one shard"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn service_snapshot_conserves_volume_for_any_stream() {
    use streamcom::service::{ClusterService, ServiceConfig};
    property("service snapshot conservation", 15, |rng, size| {
        let (_, edges) = random_stream(rng, size);
        let shards = 1 + rng.next_below(6) as usize;
        let v_max = 1 + rng.next_below(500);
        let mut cfg = ServiceConfig::new(shards, v_max);
        cfg.chunk_size = 1 + rng.next_below(64) as usize;
        let mut svc = ClusterService::start(cfg);

        // snapshot halfway through, then at the end; both must satisfy
        // the stream-end invariant Σ v_k = 2t
        let half = edges.len() / 2;
        svc.push_chunk(&edges[..half]);
        let snap = svc.quiesce();
        if snap.state().total_volume() != 2 * snap.edges() {
            return Err(format!(
                "mid-stream: Σv = {} ≠ 2·{}",
                snap.state().total_volume(),
                snap.edges()
            ));
        }
        svc.push_chunk(&edges[half..]);
        let res = svc.finish();
        if res.state().total_volume() != 2 * res.snapshot.edges() {
            return Err(format!(
                "final: Σv = {} ≠ 2·{}",
                res.state().total_volume(),
                res.snapshot.edges()
            ));
        }
        if res.edges_ingested != edges.len() as u64 {
            return Err(format!(
                "ingested {} of {} edges",
                res.edges_ingested,
                edges.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn memory_is_exactly_sixteen_bytes_per_node() {
    property("sketch memory bound", 20, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let mut c = StreamingClusterer::new(n, StrConfig::new(64));
        c.process_chunk(&edges);
        prop_assert(
            c.state.memory_bytes() == 16 * c.state.n(),
            format!("{} bytes for {} nodes", c.state.memory_bytes(), c.state.n()),
        )
    });
}
