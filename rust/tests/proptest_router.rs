//! Property tests for the unified routing core and the incremental
//! cross-edge replay, on randomly permuted multigraph streams:
//!
//! * **Replay equivalence** — for shards ∈ {1, 2, 4} and drain
//!   cadences ∈ {1, 7, 64}: the service's final partition under
//!   incremental drains is bit-identical to the full-buffer replay
//!   (`run_parallel`, which is the batch preset of the same core), and
//!   with a single shard both are bit-identical to the single-threaded
//!   `cluster_edges`.
//! * **Batch-spine equivalence** — `push_chunk` batches of any size
//!   (one-pass partitioning, pooled chunk buffers, per-batch
//!   bookkeeping) are bit-identical to per-edge `push`, across shard
//!   counts covering both the pow2 shift fast path and the generic
//!   multiplicative path.
//! * **View validity** — every incremental mid-stream snapshot is a
//!   valid partition: volume conservation `Σ v_k = 2t`, labels in
//!   node-id space, exact coverage at quiesce points.
//! * **Replay accounting** — across all drains of a run, each cross
//!   edge is replayed exactly once by the snapshot path.
//! * **Horizon degeneracy** — a commit horizon at least as long as the
//!   stream never commits an epoch, so it is semantically `Unbounded`,
//!   which is semantically the batch run: all three are bit-identical
//!   across shard counts and drain cadences.
//! * **Bounded-horizon soundness** — with a small horizon the
//!   accounting invariants (every edge exactly once, `Σ v_k = 2t`)
//!   still hold and retained cross edges respect the
//!   `horizon + one epoch` bound at every quiesce point.

use streamcom::coordinator::algorithm::cluster_edges;
use streamcom::coordinator::parallel::{run_parallel, ParallelConfig};
use streamcom::graph::edge::Edge;
use streamcom::service::{ClusterService, CommitHorizon, ServiceConfig};
use streamcom::util::proptest::property;
use streamcom::util::rng::Xoshiro256;

/// Random multigraph edge stream over `size` nodes, in random order.
fn random_stream(rng: &mut Xoshiro256, size: usize) -> (usize, Vec<Edge>) {
    let n = size.max(2);
    let m = size * 4;
    let mut edges: Vec<Edge> = (0..m)
        .map(|_| {
            let u = rng.range(0, n) as u32;
            let mut v = rng.range(0, n) as u32;
            if u == v {
                v = (v + 1) % n as u32;
            }
            Edge::new(u, v)
        })
        .collect();
    rng.shuffle(&mut edges);
    (n, edges)
}

fn pad(mut labels: Vec<u32>, n: usize) -> Vec<u32> {
    while labels.len() < n {
        labels.push(labels.len() as u32);
    }
    labels
}

#[test]
fn incremental_replay_equals_full_replay_equals_sequential() {
    property("router replay equivalence", 10, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let v_max = 1 + rng.next_below(200);
        let seq = pad(cluster_edges(n, &edges, v_max), n);

        for shards in [1usize, 2, 4] {
            // full-buffer replay: the batch preset (no mid-stream drains)
            let full = pad(
                run_parallel(n, &edges, &ParallelConfig::new(shards, v_max)).labels(),
                n,
            );
            if shards == 1 && full != seq {
                return Err(format!(
                    "shards=1 batch run diverged from sequential (v_max={v_max})"
                ));
            }

            for cadence in [1u64, 7, 64] {
                // alternate between the default unbounded horizon and a
                // bounded one at least as long as the stream: neither
                // can ever commit an epoch, so both must stay
                // bit-identical to the batch run
                let horizon = if (cadence + shards as u64) % 2 == 0 {
                    CommitHorizon::Unbounded
                } else {
                    CommitHorizon::Edges(edges.len() as u64 + rng.next_below(100))
                };
                let mut cfg = ServiceConfig::new(shards, v_max);
                cfg.drain_every = cadence;
                cfg.chunk_size = 1 + rng.next_below(32) as usize;
                cfg.horizon = horizon;
                let mut svc = ClusterService::start(cfg);
                let handle = svc.handle();

                // push in two halves with a quiesce between, so the
                // incremental leader's frozen state is actually carried
                // across shard progress, not just across one batch
                let half = edges.len() / 2;
                svc.push_chunk(&edges[..half]);
                let mid = svc.quiesce();
                if mid.edges() != half as u64 {
                    return Err(format!(
                        "shards={shards} cadence={cadence}: quiesce covers {} of {half}",
                        mid.edges()
                    ));
                }
                if mid.state().total_volume() != 2 * mid.edges() {
                    return Err(format!(
                        "shards={shards} cadence={cadence}: mid-stream Σv = {} ≠ 2·{}",
                        mid.state().total_volume(),
                        mid.edges()
                    ));
                }
                let nn = mid.state().n();
                if !mid.labels().iter().all(|&l| (l as usize) < nn) {
                    return Err(format!(
                        "shards={shards} cadence={cadence}: label out of range mid-stream"
                    ));
                }

                svc.push_chunk(&edges[half..]);
                // final incremental drain (so the replay accounting
                // below covers the whole stream), then terminal replay
                svc.quiesce();
                let res = svc.finish();
                let inc = res.snapshot.labels_padded(n);
                if inc != full {
                    let diff = inc
                        .iter()
                        .zip(&full)
                        .filter(|(a, b)| a != b)
                        .count();
                    return Err(format!(
                        "shards={shards} cadence={cadence} v_max={v_max}: incremental \
                         final diverged from full-buffer replay at {diff} nodes"
                    ));
                }

                // replay accounting: every cross edge replayed exactly
                // once by the snapshot path, however many drains ran
                let s = handle.stats();
                if s.cross_replayed_total != s.cross_drained {
                    return Err(format!(
                        "shards={shards} cadence={cadence}: replayed {} ≠ drained {}",
                        s.cross_replayed_total, s.cross_drained
                    ));
                }
                if s.cross_drained != s.cross_total {
                    return Err(format!(
                        "shards={shards} cadence={cadence}: drained {} ≠ buffered {}",
                        s.cross_drained, s.cross_total
                    ));
                }
                if shards == 1 && s.cross_total != 0 {
                    return Err("single shard must never defer an edge".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn push_batch_equals_per_edge_push_equals_sequential() {
    // the batch spine property: routing a stream as batches of any
    // size through push_chunk (one-pass partitioning, per-batch
    // bookkeeping, pooled chunks) is bit-identical to routing it one
    // edge at a time through push — and with a single shard both are
    // bit-identical to the sequential reference. Shards cover the
    // pow2 shift fast path (1, 2, 4, 8) and the generic multiplicative
    // path (3).
    property("push_batch ≡ push ≡ sequential", 6, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let v_max = 1 + rng.next_below(200);
        let seq = pad(cluster_edges(n, &edges, v_max), n);

        for shards in [1usize, 2, 4, 8, 3] {
            let mut cfg = ServiceConfig::new(shards, v_max);
            cfg.chunk_size = 1 + rng.next_below(32) as usize;
            cfg.drain_every = 1 + rng.next_below(128);

            let mut svc = ClusterService::start(cfg.clone());
            for &e in &edges {
                svc.push(e);
            }
            let per_edge = svc.finish().snapshot.labels_padded(n);

            if shards == 1 && per_edge != seq {
                return Err(format!(
                    "shards=1 per-edge service diverged from sequential (v_max={v_max})"
                ));
            }

            for batch in [1usize, 7, 64, 1024] {
                let mut svc = ClusterService::start(cfg.clone());
                for chunk in edges.chunks(batch) {
                    svc.push_chunk(chunk);
                }
                let res = svc.finish();
                if res.edges_ingested != edges.len() as u64 {
                    return Err(format!(
                        "shards={shards} batch={batch}: ingested {} of {}",
                        res.edges_ingested,
                        edges.len()
                    ));
                }
                let got = res.snapshot.labels_padded(n);
                if got != per_edge {
                    let diff = got.iter().zip(&per_edge).filter(|(a, b)| a != b).count();
                    return Err(format!(
                        "shards={shards} batch={batch} v_max={v_max}: push_batch \
                         diverged from per-edge push at {diff} nodes"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn bounded_horizon_keeps_invariants_and_retention_bound() {
    property("bounded horizon soundness", 10, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let _ = n;
        let h = 1 + rng.next_below(64);
        let mut cfg = ServiceConfig::new(2 + rng.next_below(3) as usize, 64);
        cfg.horizon = CommitHorizon::Edges(h);
        cfg.drain_every = 1 + rng.next_below(32);
        cfg.chunk_size = 1 + rng.next_below(16) as usize;
        let mut svc = ClusterService::start(cfg);
        let handle = svc.handle();

        // push in thirds with quiesce points: right after a drain the
        // commit scan has run, so retention must respect the bound
        let third = edges.len() / 3;
        for part in [&edges[..third], &edges[third..2 * third], &edges[2 * third..]] {
            svc.push_chunk(part);
            svc.quiesce();
            let s = handle.stats();
            if s.cross_retained > h + s.cross_epoch_len {
                return Err(format!(
                    "retained {} > horizon {h} + epoch {}",
                    s.cross_retained, s.cross_epoch_len
                ));
            }
            if s.cross_committed + s.cross_retained != s.cross_total {
                return Err(format!(
                    "commit accounting broken: {} + {} ≠ {}",
                    s.cross_committed, s.cross_retained, s.cross_total
                ));
            }
        }

        // bounded finality must not break edge-exactly-once or volume
        // conservation — only *which* decision history is replayed
        let res = svc.finish();
        if res.edges_ingested != edges.len() as u64 {
            return Err(format!(
                "ingested {} of {} (h={h})",
                res.edges_ingested,
                edges.len()
            ));
        }
        if res.snapshot.edges() != edges.len() as u64 {
            return Err(format!(
                "final covers {} of {} (h={h})",
                res.snapshot.edges(),
                edges.len()
            ));
        }
        if res.snapshot.local_edges + res.snapshot.cross_edges != edges.len() as u64 {
            return Err(format!(
                "local {} + cross {} ≠ {} (h={h})",
                res.snapshot.local_edges,
                res.snapshot.cross_edges,
                edges.len()
            ));
        }
        if res.state().total_volume() != 2 * edges.len() as u64 {
            return Err(format!(
                "Σv = {} ≠ 2·{} (h={h})",
                res.state().total_volume(),
                edges.len()
            ));
        }
        let nn = res.state().n();
        if !res.labels().iter().all(|&l| (l as usize) < nn) {
            return Err(format!("label out of node-id space (h={h})"));
        }
        Ok(())
    });
}

#[test]
fn drain_cadence_never_changes_edge_accounting() {
    property("drain cadence accounting", 12, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let _ = n;
        let cadence = 1 + rng.next_below(32);
        let mut cfg = ServiceConfig::new(1 + rng.next_below(5) as usize, 64);
        cfg.drain_every = cadence;
        cfg.chunk_size = 1 + rng.next_below(16) as usize;
        let mut svc = ClusterService::start(cfg);
        svc.push_chunk(&edges);
        let res = svc.finish();
        if res.edges_ingested != edges.len() as u64 {
            return Err(format!(
                "ingested {} of {} edges (cadence {cadence})",
                res.edges_ingested,
                edges.len()
            ));
        }
        if res.snapshot.local_edges + res.snapshot.cross_edges != edges.len() as u64 {
            return Err(format!(
                "local {} + cross {} ≠ {} (cadence {cadence})",
                res.snapshot.local_edges,
                res.snapshot.cross_edges,
                edges.len()
            ));
        }
        if res.state().total_volume() != 2 * edges.len() as u64 {
            return Err(format!(
                "Σv = {} ≠ 2·{} (cadence {cadence})",
                res.state().total_volume(),
                edges.len()
            ));
        }
        Ok(())
    });
}
