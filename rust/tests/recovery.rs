//! Crash/fault-injection recovery harness for the durable service.
//!
//! The durability contract under test: with `config.wal_dir` set, every
//! ingested edge hits a per-shard write-ahead log before dispatch, and
//! an epoch-aligned checkpoint is written whenever the cross log
//! commits an epoch at a quiesced cut. Recovery
//! (`ClusterService::resume`) loads the latest checkpoint, truncates
//! the WAL to its longest contiguous durable prefix (dropping any torn
//! trailing fragment), replays only the suffix past the checkpoint cut,
//! and continues the stream.
//!
//! The harness "crashes" the service with the [`FailPoint`] hook baked
//! into the config: an armed [`CrashPoint`] models a dying disk — a
//! WAL append torn mid-record, or a checkpoint that writes part of its
//! temporary file and never renames it — after which every durability
//! write is silently dropped while the in-memory service keeps running.
//! Dropping the service is the abortive process death; a fresh
//! `resume` from the same directory is the restart. The proof
//! obligation everywhere: finish the stream after the restart and the
//! final partition is **bit-identical** to the uninterrupted run, and
//! the recovery stats (`recovered_epochs`, `wal_recovered_edges`)
//! prove only the post-checkpoint suffix was replayed.
//!
//! Exactness domains (mirrors `docs/ARCHITECTURE.md` §Durability):
//! under [`CommitHorizon::Unbounded`] the final partition is
//! drain-cadence independent, so recovery from *any* crash point is
//! exact (no checkpoint ever exists — the whole WAL is the suffix).
//! Under a bounded horizon mid-stream drains freeze decisions, so
//! exactness additionally needs the recovery to land on a quiesced
//! drain cut and the restarted run to re-drain at the same schedule —
//! which is what checkpoints provide: they are only written at
//! quiesced, epoch-committed cuts.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, Ordering};

use streamcom::graph::edge::{Edge, EdgeList};
use streamcom::graph::io::write_binary_edges_with;
use streamcom::service::{
    ClusterService, CommitHorizon, CrashPoint, ServiceConfig, WalError,
};
use streamcom::stream::pscan::DirectScan;
use streamcom::util::proptest::property;
use streamcom::util::rng::Xoshiro256;

/// Bytes per WAL record (`[seq u64][u u32][v u32][check u64]`) — pinned
/// here independently so a layout change fails the byte-level tests
/// loudly instead of silently shifting their offsets.
const RECORD_BYTES: usize = 24;

static SCRATCH_ID: AtomicU32 = AtomicU32::new(0);

/// Fresh per-test WAL directory under the system temp dir.
fn scratch_dir(tag: &str) -> PathBuf {
    let id = SCRATCH_ID.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!(
        "streamcom-recovery-{}-{tag}-{id}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// Service config used throughout: explicit quiesce schedules only
/// (automatic drains disabled), small dispatch chunks.
fn base_config(shards: usize, v_max: u64, horizon: CommitHorizon) -> ServiceConfig {
    let mut cfg = ServiceConfig::new(shards, v_max);
    cfg.chunk_size = 64;
    cfg.drain_every = u64::MAX;
    cfg.horizon = horizon;
    cfg
}

/// Same, with durability on. Always built fresh so every service
/// instance gets its own unarmed [`FailPoint`].
fn durable_config(
    dir: &Path,
    shards: usize,
    v_max: u64,
    horizon: CommitHorizon,
) -> ServiceConfig {
    let mut cfg = base_config(shards, v_max, horizon);
    cfg.wal_dir = Some(dir.to_path_buf());
    cfg.wal_segment_records = 32; // small segments: exercise rotation + gc
    cfg
}

/// Random multigraph edge stream over `size` nodes, in random order
/// (same shape as the router property suite's generator).
fn random_stream(rng: &mut Xoshiro256, size: usize) -> (usize, Vec<Edge>) {
    let n = size.max(2);
    let m = size * 4;
    let mut edges: Vec<Edge> = (0..m)
        .map(|_| {
            let u = rng.range(0, n) as u32;
            let mut v = rng.range(0, n) as u32;
            if u == v {
                v = (v + 1) % n as u32;
            }
            Edge::new(u, v)
        })
        .collect();
    rng.shuffle(&mut edges);
    (n, edges)
}

fn pad(mut labels: Vec<u32>, n: usize) -> Vec<u32> {
    while labels.len() < n {
        labels.push(labels.len() as u32);
    }
    labels
}

/// Read a committed golden stream (duplicated from the golden suite —
/// integration tests are separate crates).
fn read_golden(stem: &str) -> (usize, u64, usize, Vec<Edge>) {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden")
        .join(format!("{stem}.edges"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()));
    let mut lines = text.lines().filter(|l| !l.trim_start().starts_with('#'));
    let header = lines.next().expect("missing golden header");
    let mut parts = header.split_whitespace();
    let n: usize = parts.next().unwrap().parse().unwrap();
    let v_max: u64 = parts.next().unwrap().parse().unwrap();
    let shards: usize = parts.next().unwrap().parse().unwrap();
    let edges: Vec<Edge> = lines
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let mut it = l.split_whitespace();
            Edge::new(it.next().unwrap().parse().unwrap(), it.next().unwrap().parse().unwrap())
        })
        .collect();
    (n, v_max, shards, edges)
}

/// Push `edges[from..]` in `step`-sized chunks whose boundaries fall on
/// global multiples of `step`, quiescing at every boundary — the
/// schedule both the uninterrupted reference and every restarted run
/// follow, so drains land on identical cuts.
fn push_with_schedule(svc: &mut ClusterService, edges: &[Edge], from: usize, step: usize) {
    let mut at = from;
    while at < edges.len() {
        let next = ((at / step) + 1) * step;
        let next = next.min(edges.len());
        svc.push_chunk(&edges[at..next]);
        svc.quiesce();
        at = next;
    }
}

// ---------------------------------------------------------------------
// Tentpole: kill mid-stream on the golden streams, restart, finish —
// bit-identical.
// ---------------------------------------------------------------------

/// Mid-WAL-append crashes with torn tails at several stream positions,
/// on both golden streams, under the default unbounded horizon: the
/// restarted run must finish to the exact partition of the
/// uninterrupted run, and recovery must account every surviving record.
#[test]
fn crash_mid_wal_append_recovers_bit_identical_on_golden_streams() {
    for stem in ["sbm_k6_s30", "lfr_mu015"] {
        let (n, v_max, shards, edges) = read_golden(stem);
        let m = edges.len();

        // uninterrupted reference: same config, durability off
        let mut reference = ClusterService::start(base_config(shards, v_max, CommitHorizon::Unbounded));
        reference.push_chunk(&edges);
        let want = reference.finish().snapshot.labels_padded(n);

        for (point, torn) in [(m / 7, 1usize), (m / 2, 13), (m - 2, 23)] {
            let point = point.max(1);
            let dir = scratch_dir("golden");

            // the doomed run: disk dies tearing record `point`; the
            // in-memory service keeps going until we "kill" it by drop
            let cfg = durable_config(&dir, shards, v_max, CommitHorizon::Unbounded);
            let fp = cfg.failpoint.clone();
            fp.arm(CrashPoint::WalAppend { after_records: point as u64, torn_bytes: torn });
            let mut doomed = ClusterService::start(cfg);
            for chunk in edges.chunks(97) {
                doomed.push_chunk(chunk);
            }
            assert!(fp.is_dead(), "{stem}: crash point {point} never tripped");
            drop(doomed); // abortive shutdown: nothing flushed, nothing synced

            // restart: recover, then finish the stream from where the
            // durable prefix ends
            let mut svc =
                ClusterService::resume(durable_config(&dir, shards, v_max, CommitHorizon::Unbounded))
                    .expect("resume after torn WAL append");
            let handle = svc.handle();
            let s = handle.stats();
            assert_eq!(s.edges_ingested as usize, point, "{stem}: recovered position");
            // unbounded ⇒ no epoch ever commits ⇒ no checkpoint: the
            // whole durable prefix is the replayed suffix
            assert_eq!(s.wal_recovered_edges as usize, point, "{stem}");
            assert_eq!(s.recovered_epochs, 0, "{stem}");
            assert_eq!(s.checkpoints_written, 0, "{stem}");
            assert_eq!(s.wal_bytes, 0, "{stem}: no bytes appended by this process yet");

            for chunk in edges[point..].chunks(97) {
                svc.push_chunk(chunk);
            }
            // the revived disk logs the re-pushed tail
            assert!(handle.stats().wal_bytes > 0, "{stem}");
            let res = svc.finish();
            assert_eq!(res.edges_ingested as usize, m, "{stem}");
            assert_eq!(
                res.snapshot.labels_padded(n),
                want,
                "{stem}: crash at {point} (torn {torn}B) diverged after recovery"
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

/// A checkpoint that dies mid-write (partial temporary file, never
/// renamed) must be invisible: recovery falls back to the previous
/// checkpoint, replays the WAL suffix between the two cuts, and — with
/// the restarted run re-draining on the same schedule — finishes
/// bit-identical to the uninterrupted bounded-horizon run.
#[test]
fn crash_mid_checkpoint_falls_back_to_previous_checkpoint() {
    let mut rng = Xoshiro256::new(0xD1CE);
    let (n, edges) = random_stream(&mut rng, 384); // m = 1536
    let m = edges.len();
    let (shards, leaders, v_max) = (2usize, 2usize, 32u64);
    let horizon = CommitHorizon::Edges(8); // epoch_len 2: commits every drain
    const Q: usize = 256;

    let mut reference = ClusterService::start({
        let mut cfg = base_config(shards, v_max, horizon);
        cfg.leaders = leaders;
        cfg
    });
    push_with_schedule(&mut reference, &edges, 0, Q);
    let want = reference.finish().snapshot.labels_padded(n);

    let dir = scratch_dir("ckpt");
    let mk_durable = |dir: &Path| {
        let mut cfg = durable_config(dir, shards, v_max, horizon);
        cfg.leaders = leaders;
        cfg
    };

    // arm: the third checkpoint attempt (0-based nth = 2) tears after
    // 41 bytes of its temporary file and the disk dies with it
    let cfg = mk_durable(&dir);
    let fp = cfg.failpoint.clone();
    fp.arm(CrashPoint::Checkpoint { nth: 2, keep_bytes: 41 });
    let mut doomed = ClusterService::start(cfg);
    let handle = doomed.handle();
    let mut pushed = 0usize;
    while pushed < m && !fp.is_dead() {
        doomed.push_chunk(&edges[pushed..pushed + Q]);
        doomed.quiesce();
        pushed += Q;
        if !fp.is_dead() {
            // this workload is cross-heavy enough that *every* quiesced
            // drain commits fresh epochs, i.e. every quiesce checkpoints
            // — the property the fall-back arithmetic below relies on
            assert_eq!(
                handle.stats().checkpoints_written as usize,
                pushed / Q,
                "expected a checkpoint at every quiesce (tune Q/horizon)"
            );
        }
    }
    assert!(fp.is_dead(), "checkpoint crash never tripped");
    assert_eq!(pushed, 3 * Q, "disk must die at the third checkpoint attempt");
    assert_eq!(handle.stats().checkpoints_written, 2);
    drop(doomed);

    // restart: the torn attempt is invisible — recovery lands on
    // checkpoint #1 (cut 2Q) and replays exactly one interval of WAL
    let mut svc = ClusterService::resume(mk_durable(&dir)).expect("resume past torn checkpoint");
    let handle = svc.handle();
    let s = handle.stats();
    assert_eq!(s.edges_ingested as usize, 3 * Q, "durable prefix reaches the failed cut");
    assert_eq!(s.wal_recovered_edges as usize, Q, "suffix-only replay: one interval");
    assert!(s.recovered_epochs > 0, "committed history came from the checkpoint");
    assert_eq!(s.last_checkpoint_epoch, s.recovered_epochs);

    // re-drain at the crashed run's last cut, then keep its schedule:
    // every drain of the uninterrupted run is reproduced exactly
    svc.quiesce();
    assert!(handle.stats().checkpoints_written >= 1, "revived disk checkpoints again");
    push_with_schedule(&mut svc, &edges, 3 * Q, Q);
    let res = svc.finish();
    assert_eq!(res.edges_ingested as usize, m);
    assert_eq!(
        res.snapshot.labels_padded(n),
        want,
        "bounded-horizon recovery through a torn checkpoint diverged"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Tentpole: direct-route crashes. The readers append routed chunks to
// per-reader WAL lanes before enqueueing, so a crash anywhere on the
// direct path must recover to the seq-keyed durable cut and finish
// bit-identical once the lost tail is re-fed.
// ---------------------------------------------------------------------

/// Arm `plan` on a durable **direct** ingest of `edges` (scanned from
/// `bin` at `readers` readers), kill the service by drop, resume from
/// the per-reader lanes, re-feed the stream past the recovered cut
/// through the funnel, and require the finish to be bit-identical to
/// `want`. `expect_cut` pins the exact recovered position where it is
/// deterministic (single reader).
#[allow(clippy::too_many_arguments)]
fn crash_direct_and_recover(
    stem: &str,
    bin: &Path,
    dir: &Path,
    n: usize,
    v_max: u64,
    shards: usize,
    readers: usize,
    edges: &[Edge],
    want: &[u32],
    plan: CrashPoint,
    expect_cut: Option<u64>,
) {
    let m = edges.len();
    let cfg = durable_config(dir, shards, v_max, CommitHorizon::Unbounded);
    let fp = cfg.failpoint.clone();
    fp.arm(plan.clone());
    // the service prepares the directory before the readers open lanes
    let wal_cfg = cfg.direct_wal_cfg();
    let mut doomed = ClusterService::start(cfg);
    let mut scan =
        DirectScan::open(bin, readers, 32, shards, wal_cfg).expect("open direct scan");
    doomed.ingest_direct(&mut scan);
    assert!(
        doomed.take_fault().is_none(),
        "{stem}: a dying disk is degradation, not a service fault"
    );
    assert!(fp.is_dead(), "{stem}: {plan:?} never tripped at readers={readers}");
    drop(doomed); // abortive shutdown: nothing flushed past the death

    let mut svc =
        ClusterService::resume(durable_config(dir, shards, v_max, CommitHorizon::Unbounded))
            .unwrap_or_else(|e| panic!("{stem}: resume after {plan:?} failed: {e}"));
    let s = svc.handle().stats();
    let d = s.edges_ingested as usize;
    assert!(d <= m, "{stem}: recovered past the end of the stream");
    if let Some(cut) = expect_cut {
        assert_eq!(s.edges_ingested, cut, "{stem}: {plan:?}");
    }
    // unbounded ⇒ no checkpoint ever: the whole durable prefix across
    // every per-reader lane is the replayed suffix
    assert_eq!(s.wal_recovered_edges, s.edges_ingested, "{stem}: {plan:?}");
    assert_eq!(s.recovered_epochs, 0, "{stem}");
    assert_eq!(s.checkpoints_written, 0, "{stem}");

    for chunk in edges[d..].chunks(97) {
        svc.push_chunk(chunk);
    }
    let res = svc.finish();
    assert_eq!(res.edges_ingested as usize, m, "{stem}");
    assert_eq!(
        res.snapshot.labels_padded(n),
        want,
        "{stem}: {plan:?} at readers={readers} diverged after recovery"
    );
}

/// In-memory uninterrupted reference plus the segmented binary file
/// the direct crash runs scan (written once per golden stream).
fn direct_crash_fixture(stem: &str, host: &Path) -> (usize, u64, usize, Vec<Edge>, Vec<u32>, PathBuf) {
    let (n, v_max, shards, edges) = read_golden(stem);
    let mut reference =
        ClusterService::start(base_config(shards, v_max, CommitHorizon::Unbounded));
    reference.push_chunk(&edges);
    let want = reference.finish().snapshot.labels_padded(n);
    let bin = host.join(format!("{stem}.bin"));
    // small segments so every swept reader count owns several
    write_binary_edges_with(&bin, &EdgeList::new(n, edges.clone()), 64)
        .expect("write golden binary");
    (n, v_max, shards, edges, want, bin)
}

/// A reader's lane torn mid-record at **every** byte offset: whichever
/// reader dies, whatever fragment survives, resume lands on the seq
/// cut and the re-fed stream finishes bit-identical. Single-reader
/// sweeps additionally pin the exact cut (= the armed append count,
/// since one reader's append order is the global seq order).
#[test]
fn direct_torn_reader_lane_at_every_byte_offset_recovers_bit_identical() {
    for stem in ["sbm_k6_s30", "lfr_mu015"] {
        let host = scratch_dir("direct-tear-bin");
        let (n, v_max, shards, edges, want, bin) = direct_crash_fixture(stem, &host);
        for readers in [1usize, 2, 4] {
            for torn in 0..RECORD_BYTES {
                let dir = scratch_dir("direct-tear");
                let reader = torn % readers;
                let point = 40 + torn as u64; // inside every reader's share
                crash_direct_and_recover(
                    stem,
                    &bin,
                    &dir,
                    n,
                    v_max,
                    shards,
                    readers,
                    &edges,
                    &want,
                    CrashPoint::ReaderWalAppend {
                        reader,
                        after_records: point,
                        torn_bytes: torn,
                    },
                    (readers == 1).then_some(point),
                );
                std::fs::remove_dir_all(&dir).ok();
            }
        }
        std::fs::remove_dir_all(&host).ok();
    }
}

/// The process dies between a reader's WAL flush and the queue push:
/// the flushed chunk is durable but was never ingested. Recovery must
/// replay it (it is below the durable cut unless an earlier gap
/// intervenes) and the re-fed stream must finish bit-identical — the
/// WAL-before-enqueue ordering is exactly what makes this crash
/// window lossless.
#[test]
fn direct_crash_between_wal_flush_and_enqueue_recovers_bit_identical() {
    for stem in ["sbm_k6_s30", "lfr_mu015"] {
        let host = scratch_dir("direct-enqueue-bin");
        let (n, v_max, shards, edges, want, bin) = direct_crash_fixture(stem, &host);
        for readers in [1usize, 2, 4] {
            for after_chunks in [0u64, 3] {
                let dir = scratch_dir("direct-enqueue");
                crash_direct_and_recover(
                    stem,
                    &bin,
                    &dir,
                    n,
                    v_max,
                    shards,
                    readers,
                    &edges,
                    &want,
                    CrashPoint::ReaderEnqueue { reader: readers - 1, after_chunks },
                    None,
                );
                std::fs::remove_dir_all(&dir).ok();
            }
        }
        std::fs::remove_dir_all(&host).ok();
    }
}

// ---------------------------------------------------------------------
// Satellite: recover-at-every-epoch-boundary property.
// ---------------------------------------------------------------------

/// Property: for shards × leaders × horizon combinations, kill the
/// stream at each quiesce boundary (torn WAL tail) and restart; under
/// an unbounded horizon — and under a bounded horizon whenever
/// recovery lands exactly on a checkpoint cut — the finished partition
/// is bit-identical to the uninterrupted run on the same schedule.
/// Elsewhere (bounded, recovery behind the last drain) exactness is
/// out of contract, but accounting must still balance.
#[test]
fn recovery_at_every_quiesce_boundary_matches_uninterrupted() {
    // prove the bounded exactness branch was actually exercised
    let aligned_bounded_cases = std::cell::Cell::new(0u32);
    property("recover at every quiesce boundary", 4, |rng, size| {
        let (n, edges) = random_stream(rng, size);
        let m = edges.len();
        let q = (m / 4).max(4);
        let v_max = 1 + rng.next_below(100);

        for shards in [1usize, 2, 4] {
            for leaders in [1usize, 2] {
                for horizon in [CommitHorizon::Unbounded, CommitHorizon::Edges(8)] {
                    let mut cfg = base_config(shards, v_max, horizon);
                    cfg.leaders = leaders;
                    let mut reference = ClusterService::start(cfg);
                    push_with_schedule(&mut reference, &edges, 0, q);
                    let want = reference.finish().snapshot.labels_padded(n);

                    for k in 1..4usize {
                        let cut = k * q;
                        if cut >= m {
                            break;
                        }
                        let dir = scratch_dir("prop");
                        let mut cfg = durable_config(&dir, shards, v_max, horizon);
                        cfg.leaders = leaders;
                        let fp = cfg.failpoint.clone();
                        fp.arm(CrashPoint::WalAppend {
                            after_records: cut as u64,
                            torn_bytes: 1 + (cut % (RECORD_BYTES - 1)),
                        });
                        let mut doomed = ClusterService::start(cfg);
                        push_with_schedule(&mut doomed, &edges, 0, q);
                        if !fp.is_dead() {
                            return Err(format!("tear at {cut} never tripped (m={m})"));
                        }
                        drop(doomed);

                        let mut cfg = durable_config(&dir, shards, v_max, horizon);
                        cfg.leaders = leaders;
                        let mut svc = match ClusterService::resume(cfg) {
                            Ok(svc) => svc,
                            Err(e) => return Err(format!("resume at {cut} failed: {e}")),
                        };
                        let s = svc.handle().stats();
                        if s.edges_ingested as usize != cut {
                            return Err(format!(
                                "recovered to {} instead of the boundary {cut}",
                                s.edges_ingested
                            ));
                        }
                        // shards=1 has no cross edges at all, so the
                        // bounded horizon is semantically unbounded
                        let exact = horizon.is_unbounded()
                            || shards == 1
                            || s.wal_recovered_edges == 0;
                        if !horizon.is_unbounded() && shards > 1 && s.wal_recovered_edges == 0 {
                            // landed exactly on a checkpoint cut
                            aligned_bounded_cases.set(aligned_bounded_cases.get() + 1);
                            if s.recovered_epochs == 0 {
                                return Err(format!(
                                    "boundary {cut}: empty replay but no checkpoint epochs"
                                ));
                            }
                        }
                        push_with_schedule(&mut svc, &edges, cut, q);
                        let res = svc.finish();
                        let got = res.snapshot.labels_padded(n);
                        if res.edges_ingested as usize != m {
                            return Err(format!(
                                "boundary {cut}: finished with {} of {m} edges",
                                res.edges_ingested
                            ));
                        }
                        if res.state().total_volume() != 2 * m as u64 {
                            return Err(format!(
                                "boundary {cut}: volume {} != 2m={}",
                                res.state().total_volume(),
                                2 * m
                            ));
                        }
                        if exact && got != want {
                            let diffs = got.iter().zip(&want).filter(|(a, b)| a != b).count();
                            return Err(format!(
                                "shards={shards} leaders={leaders} horizon={horizon:?} \
                                 boundary {cut}: {diffs}/{n} labels diverged after recovery"
                            ));
                        }
                        std::fs::remove_dir_all(&dir).ok();
                    }
                }
            }
        }
        Ok(())
    });
    assert!(
        aligned_bounded_cases.get() > 0,
        "no bounded case ever recovered exactly at a checkpoint cut — \
         the exactness branch went untested"
    );
}

// ---------------------------------------------------------------------
// Satellite: byte-level WAL fault injection.
// ---------------------------------------------------------------------

/// The single WAL segment written by a clean single-shard run (every
/// edge is local with one shard, so there is exactly one file set).
fn only_wal_file(dir: &Path) -> PathBuf {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .expect("read wal dir")
        .map(|e| e.expect("dir entry").path())
        .filter(|p| p.extension().is_some_and(|x| x == "wal"))
        .collect();
    assert_eq!(files.len(), 1, "expected one WAL segment, got {files:?}");
    files.pop().unwrap()
}

/// Write a 40-edge single-shard WAL to `dir` and return
/// `(n, edges, reference labels, pristine file bytes)`.
fn pristine_wal(dir: &Path) -> (usize, Vec<Edge>, Vec<u32>, Vec<u8>) {
    let n = 41usize;
    let edges: Vec<Edge> = (0u32..40).map(|i| Edge::new(i, i + 1)).collect();
    let mut reference = ClusterService::start(base_config(1, 8, CommitHorizon::Unbounded));
    reference.push_chunk(&edges);
    let want = reference.finish().snapshot.labels_padded(n);

    let mut cfg = durable_config(dir, 1, 8, CommitHorizon::Unbounded);
    cfg.wal_segment_records = 1 << 20; // single segment for byte surgery
    let mut svc = ClusterService::start(cfg);
    svc.push_chunk(&edges);
    let res = svc.finish(); // finish syncs: all 40 records durable
    assert_eq!(res.edges_ingested, 40);
    let bytes = std::fs::read(only_wal_file(dir)).expect("read pristine wal");
    assert_eq!(bytes.len(), 40 * RECORD_BYTES, "record layout changed?");
    (n, edges, want, bytes)
}

/// Truncate the WAL's last record at **every** byte offset: recovery
/// must drop the torn record cleanly every time — never panic, never
/// conjure a wrong-but-valid edge — recover exactly the 39 intact
/// records, and reach the reference partition once the lost edge is
/// re-pushed.
#[test]
fn torn_wal_tail_at_every_byte_offset_is_dropped_cleanly() {
    let dir = scratch_dir("tear");
    let (n, edges, want, pristine) = pristine_wal(&dir);
    let file = only_wal_file(&dir);

    for keep in 0..RECORD_BYTES {
        std::fs::write(&file, &pristine[..39 * RECORD_BYTES + keep]).expect("truncate tail");
        let mut svc =
            ClusterService::resume(durable_config(&dir, 1, 8, CommitHorizon::Unbounded))
                .unwrap_or_else(|e| panic!("torn tail at byte {keep} must recover, got {e}"));
        let s = svc.handle().stats();
        assert_eq!(s.edges_ingested, 39, "keep={keep}");
        assert_eq!(s.wal_recovered_edges, 39, "keep={keep}");
        svc.push_chunk(&edges[39..]);
        let res = svc.finish();
        assert_eq!(res.edges_ingested, 40, "keep={keep}");
        assert_eq!(res.snapshot.labels_padded(n), want, "keep={keep}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A *full-width* record that fails its checksum is not a torn tail —
/// it is corruption. Resume no longer refuses the whole directory: the
/// damaged segment is quarantined to `<name>.corrupt` (preserved
/// byte-for-byte for forensics), its clean prefix of whole records is
/// recovered under the original name, and the stream continues from
/// the durable cut the surviving records support.
#[test]
fn corrupt_wal_segment_is_quarantined_and_clean_prefix_recovered() {
    let dir = scratch_dir("corrupt");
    let (n, edges, want, pristine) = pristine_wal(&dir);
    let file = only_wal_file(&dir);
    let mut quarantine = file.clone().into_os_string();
    quarantine.push(".corrupt");
    let quarantine = PathBuf::from(quarantine);

    // flip one byte of record 10's payload; its checksum now fails
    let mut bytes = pristine.clone();
    bytes[10 * RECORD_BYTES + 13] ^= 0x5A;
    std::fs::write(&file, &bytes).expect("write corrupted wal");
    let mut svc = ClusterService::resume(durable_config(&dir, 1, 8, CommitHorizon::Unbounded))
        .expect("quarantine must let resume proceed");
    let s = svc.handle().stats();
    assert_eq!(s.edges_ingested, 10, "clean prefix before the damage");
    assert_eq!(s.wal_recovered_edges, 10);
    assert_eq!(
        std::fs::read(&quarantine).expect("quarantined segment"),
        bytes,
        "forensic copy must preserve the damaged bytes exactly"
    );
    assert_eq!(
        std::fs::metadata(&file).expect("recovered segment").len(),
        (10 * RECORD_BYTES) as u64,
        "recovered segment holds exactly the clean prefix"
    );
    svc.push_chunk(&edges[10..]);
    let res = svc.finish();
    assert_eq!(res.edges_ingested, 40);
    assert_eq!(res.snapshot.labels_padded(n), want, "post-quarantine finish diverged");

    // a checksum-valid record with a regressed sequence number is
    // equally corrupt (duplicated/reordered history, not a torn tail)
    // and quarantines the same way, keeping the records before it
    let mut bytes = pristine.clone();
    let dup: [u8; RECORD_BYTES] = bytes[..RECORD_BYTES].try_into().unwrap();
    bytes[20 * RECORD_BYTES..21 * RECORD_BYTES].copy_from_slice(&dup);
    std::fs::write(&file, &bytes).expect("write regressed wal");
    std::fs::remove_file(&quarantine).ok();
    let mut svc = ClusterService::resume(durable_config(&dir, 1, 8, CommitHorizon::Unbounded))
        .expect("sequence regression must quarantine, not fail");
    let s = svc.handle().stats();
    assert_eq!(s.edges_ingested, 20, "clean prefix before the regression");
    assert!(quarantine.exists(), "regressed segment preserved for forensics");
    svc.push_chunk(&edges[20..]);
    let res = svc.finish();
    assert_eq!(res.snapshot.labels_padded(n), want, "post-regression finish diverged");
    std::fs::remove_dir_all(&dir).ok();
}

/// Checkpoints have no quarantine path — a `checkpoint.bin` whose
/// trailing checksum fails is the typed [`WalError::Corrupt`] (naming
/// the file), never a panic and never a silent fresh start over
/// durable state.
#[test]
fn corrupt_checkpoint_yields_typed_error_not_panic() {
    let mut rng = Xoshiro256::new(0xBADC);
    let (_n, edges) = random_stream(&mut rng, 192); // m = 768
    let (shards, v_max) = (2usize, 32u64);
    let horizon = CommitHorizon::Edges(8);

    let dir = scratch_dir("ckpt-corrupt");
    let mut svc = ClusterService::start(durable_config(&dir, shards, v_max, horizon));
    let handle = svc.handle();
    push_with_schedule(&mut svc, &edges, 0, 256);
    assert!(handle.stats().checkpoints_written >= 1, "need a checkpoint to damage");
    drop(svc);

    let ckpt = dir.join("checkpoint.bin");
    let mut bytes = std::fs::read(&ckpt).expect("read checkpoint");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x5A;
    std::fs::write(&ckpt, &bytes).expect("write damaged checkpoint");
    let err = ClusterService::resume(durable_config(&dir, shards, v_max, horizon))
        .err()
        .expect("damaged checkpoint must fail resume");
    match err {
        WalError::Corrupt { ref file, .. } => {
            assert!(file.ends_with("checkpoint.bin"), "error names {}", file.display());
        }
        other => panic!("expected WalError::Corrupt, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Resuming under a configuration that does not match the checkpoint's
/// fingerprint — or without a WAL directory at all — is a typed
/// `Mismatch`, never a silent reinterpretation of durable state.
#[test]
fn mismatched_resume_configuration_yields_typed_error() {
    let mut rng = Xoshiro256::new(0xFEED);
    let (_n, edges) = random_stream(&mut rng, 192); // m = 768
    let (shards, leaders, v_max) = (2usize, 2usize, 32u64);
    let horizon = CommitHorizon::Edges(8);

    let dir = scratch_dir("mismatch");
    let mk = |dir: &Path, shards: usize, leaders: usize, v_max: u64, horizon: CommitHorizon| {
        let mut cfg = durable_config(dir, shards, v_max, horizon);
        cfg.leaders = leaders;
        cfg
    };
    let mut svc = ClusterService::start(mk(&dir, shards, leaders, v_max, horizon));
    let handle = svc.handle();
    push_with_schedule(&mut svc, &edges, 0, 256);
    assert!(handle.stats().checkpoints_written >= 1, "need a checkpoint to fingerprint");
    drop(svc); // abort mid-stream; the checkpoint + WAL stay behind

    let wrong = [
        mk(&dir, 3, leaders, v_max, horizon),                    // shard count
        mk(&dir, shards, 1, v_max, horizon),                     // leader count
        mk(&dir, shards, leaders, v_max + 1, horizon),           // v_max
        mk(&dir, shards, leaders, v_max, CommitHorizon::Unbounded), // horizon
    ];
    for cfg in wrong {
        let err = ClusterService::resume(cfg).err().expect("fingerprint mismatch must fail");
        assert!(matches!(err, WalError::Mismatch { .. }), "got {err:?}");
    }
    let err = ClusterService::resume(base_config(shards, v_max, horizon))
        .err()
        .expect("resume without wal_dir must fail");
    assert!(matches!(err, WalError::Mismatch { .. }), "got {err:?}");

    // and the matching fingerprint still resumes fine afterwards
    let svc = ClusterService::resume(mk(&dir, shards, leaders, v_max, horizon))
        .expect("matching fingerprint must resume");
    assert!(svc.handle().stats().recovered_epochs > 0);
    drop(svc);
    std::fs::remove_dir_all(&dir).ok();
}
