#!/usr/bin/env python3
"""Generate the golden streams and expected label vectors.

This is an exact, independently-written port of the repo's decision
rule (`rust/src/coordinator/algorithm.rs::process_edge`, paper
defaults: BothAtMost threshold, j-joins-i tie-break, volume condition),
the shard hash (`rust/src/stream/shard.rs::shard_of`), and the batch
replay semantics (per-shard local processing in stream order, then
cross-edge replay in arrival order over the merged sketch —
`service::router` / `coordinator::parallel::run_parallel`).

Because hash-sharding makes shard-local state cells fully disjoint
(communities never span shards before cross replay), "process local
edges in stream order on one sketch, then replay the cross edges in
order" is *exactly* the merged-shards-then-replay pipeline; the port
exploits that to stay small.

The port double-checks itself against the upstream unit-test fixtures
(first-edge walkthrough, two-triangles cases, conservation) before
writing anything. The committed .edges/.labels files are the source of
truth for `golden_partitions.rs`; this script documents their
provenance and regenerates them without a Rust toolchain. With a
toolchain, `GOLDEN_REGEN=1 cargo test --test golden_partitions`
regenerates the label files from the Rust implementation itself.
"""

import random
from pathlib import Path

HERE = Path(__file__).resolve().parent
UNSEEN = -1
MASK64 = (1 << 64) - 1
FIB = 0x9E37_79B9_7F4A_7C15


def shard_of(node: int, shards: int) -> int:
    h = (node * FIB) & MASK64
    return ((h >> 32) * shards) >> 32


class Sketch:
    """The three-integers-per-node sketch."""

    def __init__(self, n: int):
        self.deg = [0] * n
        self.com = [UNSEEN] * n
        self.vol = [0] * n
        self.t = 0

    def process_edge(self, u: int, v: int, vmax: int) -> None:
        if u == v:
            return
        if self.com[u] == UNSEEN:
            self.com[u] = u
        if self.com[v] == UNSEEN:
            self.com[v] = v
        self.deg[u] += 1
        self.deg[v] += 1
        ci = self.com[u]
        cj = self.com[v]
        self.vol[ci] += 1
        self.vol[cj] += 1
        self.t += 1
        if ci == cj:
            return
        vi = self.vol[ci]
        vj = self.vol[cj]
        if vi <= vmax and vj <= vmax:
            if vi < vj:  # i joins j's community
                d = self.deg[u]
                self.vol[cj] += d
                self.vol[ci] -= d
                self.com[u] = cj
            else:  # vi > vj, or tie -> j joins i (paper tie-break)
                d = self.deg[v]
                self.vol[ci] += d
                self.vol[cj] -= d
                self.com[v] = ci

    def labels(self):
        return [c if c != UNSEEN else i for i, c in enumerate(self.com)]


def sequential(n, edges, vmax):
    st = Sketch(n)
    for u, v in edges:
        st.process_edge(u, v, vmax)
    return st.labels()


def parallel(n, edges, vmax, shards):
    """Batch semantics: local edges in stream order, then cross replay.

    Shard-local cells are disjoint, so one sketch suffices (see module
    docstring)."""
    st = Sketch(n)
    cross = []
    for u, v in edges:
        if shard_of(u, shards) == shard_of(v, shards):
            st.process_edge(u, v, vmax)
        else:
            cross.append((u, v))
    for u, v in cross:
        st.process_edge(u, v, vmax)
    return st.labels()


def self_check():
    # paper walkthrough, first edge (algorithm.rs::paper_walkthrough_first_edge)
    st = Sketch(2)
    st.process_edge(0, 1, 8)
    assert st.com == [0, 0], st.com
    assert st.vol == [2, 0], st.vol
    assert st.deg == [1, 1], st.deg

    # two triangles bridged by one edge (algorithm.rs fixtures)
    tri = [(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5), (2, 3)]
    lab4 = sequential(6, tri, 4)
    assert lab4[0] == lab4[1] == lab4[2], lab4
    assert lab4[3] == lab4[4] == lab4[5], lab4
    assert lab4[0] != lab4[3], lab4
    lab_inf = sequential(6, tri, 1_000_000)
    assert lab_inf[0] == lab_inf[1] == lab_inf[2] == lab_inf[3], lab_inf
    assert len(set(lab_inf)) <= 2, lab_inf
    lab1 = sequential(6, tri, 1)
    assert lab1[0] != lab1[3], lab1

    # conservation after every edge, and multigraph handling
    st = Sketch(6)
    for i, (u, v) in enumerate(tri + [(0, 1)]):
        st.process_edge(u, v, 4)
        assert sum(st.vol) == 2 * (i + 1), (i, st.vol)

    # volume == sum of member degrees (the merge/drain invariant)
    vol = [0] * 6
    for i, c in enumerate(st.com):
        if c != UNSEEN:
            vol[c] += st.deg[i]
    assert vol == st.vol, (vol, st.vol)

    # shard hash: in range, deterministic, single shard collapses to 0
    for shards in (1, 2, 4, 16):
        for node in range(500):
            s = shard_of(node, shards)
            assert 0 <= s < shards
    assert all(shard_of(x, 1) == 0 for x in range(100))

    # parallel(shards=1) must equal sequential bit for bit
    rnd = random.Random(99)
    edges = [(rnd.randrange(40), rnd.randrange(40)) for _ in range(300)]
    edges = [(u, v) for u, v in edges if u != v]
    assert parallel(40, edges, 16, 1) == sequential(40, edges, 16)


def randbelow(rnd, n: int) -> int:
    """Uniform int in [0, n), derived only from Random.random().

    CPython guarantees cross-version sequence stability for random()
    alone; randrange/shuffle/sample are "subject to change", so the
    generators below never touch them. The float has 53 random bits —
    far more than these tiny ranges need — and IEEE-754 arithmetic is
    platform-deterministic, so regeneration is byte-stable anywhere."""
    return min(int(rnd.random() * n), n - 1)


def stable_shuffle(rnd, xs) -> None:
    """Fisher-Yates on top of randbelow (version-stable, see above)."""
    for i in range(len(xs) - 1, 0, -1):
        j = randbelow(rnd, i + 1)
        xs[i], xs[j] = xs[j], xs[i]


def gen_sbm(rnd, k, size, p_in, p_out):
    """SBM-shaped stream: k equal blocks, Bernoulli intra/inter pairs."""
    n = k * size
    edges = []
    for u in range(n):
        for v in range(u + 1, n):
            p = p_in if u // size == v // size else p_out
            if rnd.random() < p:
                edges.append((u, v))
    stable_shuffle(rnd, edges)
    return n, edges


def gen_lfr(rnd, sizes, intra_factor, mu):
    """LFR-shaped stream: power-law-ish community sizes, ring + random
    intra edges per community, plus a mu-fraction of inter edges."""
    n = sum(sizes)
    starts = []
    acc = 0
    for s in sizes:
        starts.append(acc)
        acc += s
    edges = []
    for start, s in zip(starts, sizes):
        members = list(range(start, start + s))
        for i in range(s):  # ring keeps each community connected
            edges.append((members[i], members[(i + 1) % s]))
        for _ in range(int(s * intra_factor)):
            u = members[randbelow(rnd, s)]
            v = members[randbelow(rnd, s)]
            while v == u:
                v = members[randbelow(rnd, s)]
            edges.append((u, v))
    inter = int(mu * len(edges))
    for _ in range(inter):
        u = randbelow(rnd, n)
        v = randbelow(rnd, n)
        while v == u:
            v = randbelow(rnd, n)
        edges.append((u, v))
    stable_shuffle(rnd, edges)
    return n, edges


def artifacts():
    """All golden files as {filename: content}, fully deterministic."""
    out = {}

    def emit(stem, title, n, edges, vmax, shards):
        header = (
            f"# golden stream: {title}\n"
            f"# format: first line 'n v_max shards', then one 'u v' edge per line\n"
            f"# (arrival order matters — do not sort)\n"
            f"{n} {vmax} {shards}\n"
        )
        out[f"{stem}.edges"] = header + "".join(f"{u} {v}\n" for u, v in edges)
        seq = sequential(n, edges, vmax)
        par = parallel(n, edges, vmax, shards)
        out[f"{stem}.seq.labels"] = "".join(f"{l}\n" for l in seq)
        out[f"{stem}.par{shards}.labels"] = "".join(f"{l}\n" for l in par)
        print(
            f"{stem}: n={n} m={len(edges)} vmax={vmax} shards={shards} "
            f"communities seq={len(set(seq))} par={len(set(par))}"
        )

    rnd = random.Random(0x5EED_60_1D)
    n, edges = gen_sbm(rnd, k=6, size=30, p_in=0.35, p_out=0.01)
    emit("sbm_k6_s30", "SBM-shaped, 6 blocks x 30 nodes, seed 0x5EED601D", n, edges, 32, 4)

    rnd = random.Random(0x1F2_60_1D)
    sizes = [50, 35, 25, 18, 13, 9, 6, 4]
    n, edges = gen_lfr(rnd, sizes, intra_factor=3.0, mu=0.15)
    emit("lfr_mu015", "LFR-shaped, power-law sizes 50..4, mu=0.15, seed 0x1F2601D", n, edges, 64, 4)

    return out


def main():
    import sys

    self_check()
    files = artifacts()
    if "--check" in sys.argv:
        # CI mode: the committed files must match what this port produces
        drift = []
        for name, content in sorted(files.items()):
            on_disk = (HERE / name).read_text() if (HERE / name).exists() else None
            if on_disk != content:
                drift.append(name)
        if drift:
            raise SystemExit(
                f"regen.py --check: committed goldens drifted from the port: {drift} "
                f"(run regen.py to regenerate, then review the diff)"
            )
        print("regen.py: port self-checks passed; committed goldens match")
        return
    for name, content in files.items():
        (HERE / name).write_text(content)


if __name__ == "__main__":
    main()
