//! Edge I/O hardening end-to-end: the segmented binary format rejects
//! hostile headers, truncation, and payload corruption *before* it
//! costs memory; the writer refuses silent id truncation; the shared
//! line-framing loop gives the strict reader, the lenient transport,
//! and the parallel text scan the same view of the same bytes (fuzzed
//! across buffer-refill boundaries); and the parallel scan reproduces
//! the single-reader service partition bit-for-bit on golden SBM/LFR
//! streams at every swept reader count — through the buffered readers
//! and through the zero-copy mmap transport (`open_mmap`), seeded and
//! unseeded, with the same hostile-input rejections at open.

use std::io::ErrorKind;
use std::path::{Path, PathBuf};

use streamcom::graph::binfmt::{self, SegHeader};
use streamcom::graph::edge::{Edge, EdgeList};
use streamcom::graph::generators::lfr::{self, LfrConfig};
use streamcom::graph::generators::sbm::{self, SbmConfig};
use streamcom::graph::io::{
    read_binary_edges, read_text_edges, write_binary_edges, write_binary_edges_with,
    write_text_edges,
};
use streamcom::service::{ClusterService, ServiceConfig};
use streamcom::stream::pscan::ParallelScanner;
use streamcom::stream::source::TextFileSource;
use streamcom::stream::EdgeSource;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sc_edge_io_{}_{name}", std::process::id()))
}

fn lcg(state: &mut u64) -> u64 {
    *state = state
        .wrapping_mul(6364136223846793005)
        .wrapping_add(1442695040888963407);
    *state >> 33
}

/// Drain an [`EdgeSource`] to a flat edge vector.
fn drain<S: EdgeSource>(src: &mut S) -> Vec<Edge> {
    let mut out = Vec::new();
    let mut buf = Vec::with_capacity(1024);
    while src.next_batch(&mut buf) > 0 {
        out.extend_from_slice(&buf);
    }
    out
}

// --- hostile headers, truncation, corruption ------------------------

#[test]
fn hostile_header_is_rejected_before_any_allocation() {
    // a syntactically valid header whose m claims 2^61 records: the
    // reader must bound-check against the real file length and fail
    // with InvalidData instead of attempting a ~2 EiB allocation
    let path = tmp("hostile_header.bin");
    let header = SegHeader::new(4, 1 << 61, binfmt::DEFAULT_SEG_RECORDS).unwrap();
    std::fs::write(&path, header.encode()).unwrap();
    let err = read_binary_edges(&path).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData, "{err}");
    assert!(err.to_string().contains("hostile"), "{err}");
    std::fs::remove_file(&path).ok();

    // the legacy shape of the bug: a tiny file (old 16-byte header
    // size) claiming a huge edge count — too short to even hold the
    // v2 header, and it must error rather than trust any field
    let path = tmp("hostile_short.bin");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"SSEG");
    bytes.extend_from_slice(&2u32.to_le_bytes());
    bytes.extend_from_slice(&(1u64 << 61).to_le_bytes());
    assert_eq!(bytes.len(), 16);
    std::fs::write(&path, &bytes).unwrap();
    assert!(read_binary_edges(&path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_and_corrupted_files_are_detected() {
    let edges: Vec<Edge> = (0..300u32).map(|i| Edge::new(i, i + 1)).collect();
    let el = EdgeList::new(301, edges);
    let path = tmp("corrupt.bin");
    write_binary_edges_with(&path, &el, 64).unwrap();
    let clean = std::fs::read(&path).unwrap();

    // truncation: file length no longer matches the segment table
    std::fs::write(&path, &clean[..clean.len() - 5]).unwrap();
    let err = read_binary_edges(&path).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData, "{err}");

    // bit flip in the payload of segment 2: the checksum names it
    let mut dirty = clean.clone();
    let seg2 = binfmt::HEADER_BYTES + 2 * (16 + 64 * 8);
    dirty[seg2 + 8 + 11] ^= 0x40;
    std::fs::write(&path, &dirty).unwrap();
    let err = read_binary_edges(&path).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData, "{err}");
    assert!(err.to_string().contains("checksum"), "{err}");
    assert!(err.to_string().contains("segment 2"), "{err}");

    // intact bytes still round-trip
    std::fs::write(&path, &clean).unwrap();
    let got = read_binary_edges(&path).unwrap();
    assert_eq!(got.n, el.n);
    assert_eq!(got.edges, el.edges);
    std::fs::remove_file(&path).ok();
}

#[test]
fn mmap_open_path_rejects_hostile_and_corrupt_files_as_invalid_data() {
    // the same three attacks, routed through the zero-copy open path:
    // every one must surface as InvalidData *at open* — validated
    // against the mapped length before any segment is dereferenced, so
    // a short map can never fault mid-scan
    let path = tmp("mmap_hostile_header.bin");
    let header = SegHeader::new(4, 1 << 61, binfmt::DEFAULT_SEG_RECORDS).unwrap();
    std::fs::write(&path, header.encode()).unwrap();
    let err = ParallelScanner::open_mmap(&path, 4, 4096).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData, "{err}");
    std::fs::remove_file(&path).ok();

    let edges: Vec<Edge> = (0..300u32).map(|i| Edge::new(i, i + 1)).collect();
    let el = EdgeList::new(301, edges);
    let path = tmp("mmap_corrupt.bin");
    write_binary_edges_with(&path, &el, 64).unwrap();
    let clean = std::fs::read(&path).unwrap();

    // truncation is caught by the mapped-length cross-check at open
    std::fs::write(&path, &clean[..clean.len() - 5]).unwrap();
    let err = ParallelScanner::open_mmap(&path, 4, 4096).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData, "{err}");

    // a bit flip inside segment 2 streams the clean prefix, then parks
    // an error naming the segment (the in-place checksum catches it)
    let mut dirty = clean.clone();
    let seg2 = binfmt::HEADER_BYTES + 2 * (16 + 64 * 8);
    dirty[seg2 + 8 + 11] ^= 0x40;
    std::fs::write(&path, &dirty).unwrap();
    let mut scan = ParallelScanner::open_mmap(&path, 1, 4096).unwrap();
    let got = drain(&mut scan);
    assert!(got.len() < el.edges.len());
    let msg = scan.take_error().expect("corruption must park an error");
    assert!(msg.contains("segment 2"), "{msg}");
    std::fs::remove_file(&path).ok();
}

#[cfg(unix)]
#[test]
fn mmap_source_error_kinds_match_the_buffered_reader() {
    // same attacks straight through MmapBinarySource (no fallback in
    // the way on unix): error kinds must match read_binary_edges
    use streamcom::stream::source::MmapBinarySource;

    let path = tmp("mmap_src_hostile.bin");
    let header = SegHeader::new(4, 1 << 61, binfmt::DEFAULT_SEG_RECORDS).unwrap();
    std::fs::write(&path, header.encode()).unwrap();
    assert_eq!(
        MmapBinarySource::open(&path).unwrap_err().kind(),
        read_binary_edges(&path).unwrap_err().kind()
    );
    // a sub-header file too
    std::fs::write(&path, [0u8; 20]).unwrap();
    assert_eq!(MmapBinarySource::open(&path).unwrap_err().kind(), ErrorKind::InvalidData);
    std::fs::remove_file(&path).ok();
}

#[test]
fn writer_hard_errors_instead_of_truncating_node_ids() {
    let el = EdgeList::new((1usize << 32) + 1, Vec::new());
    let path = tmp("oversized_n.bin");
    let err = write_binary_edges(&path, &el).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidInput, "{err}");
    assert!(!path.exists() || std::fs::remove_file(&path).is_ok());
}

// --- shared line framing: strict / lenient / parallel agree ---------

/// ~2.5 MB of messy text: valid edges, comments, blank lines, garbage
/// tokens, and occasional very long pad runs so that lines straddle
/// the 1 MiB `fill_buf` refill boundary and exercise the carry path.
fn write_messy_text(path: &Path, seed: u64) -> Vec<(u64, u64)> {
    let mut s = String::new();
    let mut rng = seed;
    let mut valid = Vec::new();
    while s.len() < 2_500_000 {
        match lcg(&mut rng) % 8 {
            0 => s.push_str("# comment line\n"),
            1 => s.push('\n'),
            2 => s.push_str("garbage tokens here\n"),
            3 => {
                // pad with trailing spaces to fuzz the refill boundary
                let pad = (lcg(&mut rng) % 4000) as usize;
                let u = lcg(&mut rng) % 100_000;
                let v = u + 1 + lcg(&mut rng) % 1000;
                s.push_str(&format!("{u}\t{v}{}\n", " ".repeat(pad)));
                valid.push((u, v));
            }
            _ => {
                let u = lcg(&mut rng) % 100_000;
                let v = u + 1 + lcg(&mut rng) % 1000;
                s.push_str(&format!("{u} {v}\n"));
                valid.push((u, v));
            }
        }
    }
    std::fs::write(path, s.as_bytes()).unwrap();
    valid
}

#[test]
fn framing_is_identical_across_lenient_strict_and_parallel_paths() {
    let path = tmp("messy.txt");
    let expected = write_messy_text(&path, 0xfeed);

    // lenient transport (TextFileSource) sees exactly the valid pairs
    let mut single = TextFileSource::open(&path).unwrap();
    let lenient = drain(&mut single);
    assert_eq!(lenient.len(), expected.len());
    for (e, (u, v)) in lenient.iter().zip(&expected) {
        assert_eq!((e.u as u64, e.v as u64), (*u, *v));
    }
    assert_eq!(single.malformed_skipped(), 0);
    assert_eq!(single.oversized_skipped(), 0);

    // parallel text scan re-emits the same stream at any reader count
    for readers in 1..=4 {
        let mut scan = ParallelScanner::open(&path, readers, 777).unwrap();
        let got = drain(&mut scan);
        assert_eq!(got, lenient, "readers={readers}");
        assert_eq!(scan.take_error(), None);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn strict_reader_agrees_with_lenient_transport_on_clean_files() {
    // a clean file (no malformed targets, ids < 2^32): the strict
    // interner and the lenient raw-id transport must describe the same
    // edge sequence — pinned through the intern back-map
    let path = tmp("clean.txt");
    let mut s = String::from("# clean edges\n");
    let mut rng = 0xbeefu64;
    for _ in 0..50_000 {
        let u = lcg(&mut rng) % 1_000_000;
        let v = u + 1 + lcg(&mut rng) % 97;
        s.push_str(&format!("{u}\t{v}\n"));
    }
    std::fs::write(&path, s.as_bytes()).unwrap();

    let (el, back) = read_text_edges(&path).unwrap();
    let mut src = TextFileSource::open(&path).unwrap();
    let lenient = drain(&mut src);
    assert_eq!(el.edges.len(), lenient.len());
    for (strict, raw) in el.edges.iter().zip(&lenient) {
        assert_eq!(back[strict.u as usize], raw.u as u64);
        assert_eq!(back[strict.v as usize], raw.v as u64);
    }
    std::fs::remove_file(&path).ok();
}

// --- convert round trip at the io layer -----------------------------

#[test]
fn text_binary_text_round_trip_is_lossless() {
    let g = sbm::generate(&SbmConfig::equal(6, 25, 0.3, 0.01, 42));
    let t1 = tmp("rt1.txt");
    let b = tmp("rt.bin");
    let t2 = tmp("rt2.txt");

    write_text_edges(&t1, &g.edges).unwrap();
    let (el1, back1) = read_text_edges(&t1).unwrap();
    // multi-segment on purpose: seg_records far below m
    write_binary_edges_with(&b, &el1, 128).unwrap();
    let el2 = read_binary_edges(&b).unwrap();
    assert_eq!(el1.edges, el2.edges);
    assert_eq!(el1.n, el2.n);

    write_text_edges(&t2, &el2).unwrap();
    let (el3, back3) = read_text_edges(&t2).unwrap();
    assert_eq!(el1.edges.len(), el3.edges.len());
    for (a, c) in el1.edges.iter().zip(&el3.edges) {
        assert_eq!(back1[a.u as usize], back3[c.u as usize]);
        assert_eq!(back1[a.v as usize], back3[c.v as usize]);
    }
    for p in [t1, b, t2] {
        std::fs::remove_file(&p).ok();
    }
}

// --- parallel scan × service: golden-stream partition parity --------

fn assert_scan_partition_parity(name: &str, el: &EdgeList) {
    let shards = 4;
    let v_max = 128;
    let baseline = {
        let mut svc = ClusterService::start(ServiceConfig::new(shards, v_max));
        for chunk in el.edges.chunks(4096) {
            svc.push_chunk(chunk);
        }
        svc.finish().labels()
    };

    let txt = tmp(&format!("{name}.txt"));
    let bin = tmp(&format!("{name}.bin"));
    write_text_edges(&txt, el).unwrap();
    write_binary_edges_with(&bin, el, 1024).unwrap();

    for path in [&txt, &bin] {
        for readers in [1usize, 2, 4] {
            let mut svc = ClusterService::start(ServiceConfig::new(shards, v_max));
            let mut scan = ParallelScanner::open(path, readers, 4096).unwrap();
            svc.ingest(&mut scan, 4096);
            assert_eq!(scan.take_error(), None, "{name} {path:?} readers={readers}");
            let res = svc.finish();
            assert_eq!(res.edges_ingested, el.m() as u64, "{name} readers={readers}");
            assert_eq!(
                res.labels(),
                baseline,
                "{name} {path:?} readers={readers}: scanned partition diverged"
            );
        }
    }

    // the zero-copy transport: one shared mapping, same partition
    // bit-for-bit at every reader count (buffered fallback on non-unix
    // builds makes this loop meaningful everywhere)
    for readers in [1usize, 2, 4] {
        let mut svc = ClusterService::start(ServiceConfig::new(shards, v_max));
        let mut scan = ParallelScanner::open_mmap(&bin, readers, 4096).unwrap();
        svc.ingest(&mut scan, 4096);
        assert_eq!(scan.take_error(), None, "{name} mmap readers={readers}");
        let res = svc.finish();
        assert_eq!(res.edges_ingested, el.m() as u64, "{name} mmap readers={readers}");
        assert_eq!(
            res.labels(),
            baseline,
            "{name} mmap readers={readers}: mapped partition diverged"
        );
    }

    // the serve fast path: sketches seeded from the header's n. The
    // pre-size changes only the label-vector length, so parity is
    // asserted through padded labels.
    {
        let baseline_padded = {
            let mut svc = ClusterService::start(ServiceConfig::new(shards, v_max));
            for chunk in el.edges.chunks(4096) {
                svc.push_chunk(chunk);
            }
            svc.finish().snapshot.labels_padded(el.n)
        };
        let mut config = ServiceConfig::new(shards, v_max);
        config.initial_nodes = el.n;
        let mut svc = ClusterService::start(config);
        let mut scan = ParallelScanner::open_mmap(&bin, 4, 4096).unwrap();
        svc.ingest(&mut scan, 4096);
        assert_eq!(scan.take_error(), None, "{name} seeded mmap");
        let res = svc.finish();
        assert_eq!(
            res.snapshot.labels_padded(el.n),
            baseline_padded,
            "{name}: seeding the sketches from the header's n changed the partition"
        );
    }
    std::fs::remove_file(&txt).ok();
    std::fs::remove_file(&bin).ok();
}

#[test]
fn parallel_scan_partition_matches_single_reader_on_golden_sbm() {
    let g = sbm::generate(&SbmConfig::equal(10, 50, 0.3, 0.002, 1712));
    assert_scan_partition_parity("sbm", &g.edges);
}

#[test]
fn parallel_scan_partition_matches_single_reader_on_golden_lfr() {
    let g = lfr::generate(&LfrConfig::named("lfr-io", 600, 10.0, 0.3, 433));
    assert_scan_partition_parity("lfr", &g.edges);
}
