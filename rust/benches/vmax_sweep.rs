//! Bench S1 — the §2.5 multi-parameter experiment: sweep `v_max` over a
//! geometric ladder on each workload, score each sweep with the
//! sketch-only metrics, and compare the sketch-selected winner against
//! the F1-optimal choice (which a streaming system cannot know).
//!
//! Uses the PJRT metric engine when artifacts are available, else the
//! native engine (printed in the header).

use streamcom::bench::report::Table;
use streamcom::bench::workloads;
use streamcom::coordinator::selection::{
    select, MetricEngine, NativeEngine, SelectionRule,
};
use streamcom::coordinator::sweep::MultiSweep;
use streamcom::graph::generators::presets::SNAP_PRESETS;
use streamcom::metrics::f1::average_f1_labels;
use streamcom::runtime::PjrtEngine;

fn main() {
    let scale: f64 = std::env::var("SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05);

    let mut pjrt = PjrtEngine::load_default().ok();
    let engine_name = if pjrt.is_some() { "pjrt" } else { "native" };
    println!("# S1: v_max sweep at scale {scale}, engine = {engine_name}\n");

    let mut table = Table::new(
        "S1 — sketch-only selection vs F1-optimal v_max",
        &["dataset", "ladder", "selected", "F1(sel)", "best", "F1(best)", "regret"],
    );
    for preset in &SNAP_PRESETS[..4] {
        let g = workloads::load_preset(preset, scale, true);
        let truth = g.truth.to_labels(g.n());
        let avg_deg = (2 * g.m() / g.n()).max(4) as u64;
        let ladder = MultiSweep::geometric_ladder(avg_deg, 8);
        let mut sweep = MultiSweep::new(g.n(), ladder.clone());
        sweep.process_chunk(&g.edges.edges);

        let engine: &mut dyn MetricEngine = match &mut pjrt {
            Some(e) => e,
            None => &mut NativeEngine,
        };
        let (winner, _) = select(&sweep, engine, SelectionRule::DensityScore);

        let f1s: Vec<f64> = (0..ladder.len())
            .map(|a| average_f1_labels(&sweep.labels(a), &truth))
            .collect();
        let best = f1s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        table.push_row(vec![
            g.name.clone(),
            format!("{}..{}", ladder[0], ladder[ladder.len() - 1]),
            ladder[winner].to_string(),
            format!("{:.3}", f1s[winner]),
            ladder[best].to_string(),
            format!("{:.3}", f1s[best]),
            format!("{:.1}%", 100.0 * (f1s[best] - f1s[winner]) / f1s[best].max(1e-9)),
        ]);
    }
    println!("{}", table.render());
    println!("regret = how much F1 the sketch-only §2.5 selection loses vs oracle");
}
