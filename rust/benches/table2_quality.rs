//! Bench T2 — regenerates the paper's Table 2 (average F1 + NMI).
//! `cargo bench --bench table2_quality` (env `SCALE=` to change scale).

use streamcom::bench::table2::{run, Table2Config};

fn main() {
    let scale: f64 = std::env::var("SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(streamcom::bench::workloads::DEFAULT_SCALE);
    let cfg = Table2Config { scale, ..Default::default() };
    eprintln!("# T2: generating workloads at scale {scale} (cached under target/workloads)");
    let (table, rows) = run(&cfg);
    println!("{}", table.render());

    println!("paper-shape checks (STR vs Louvain on the large rows):");
    for r in rows.iter().filter(|r| {
        matches!(r.name.as_str(), "youtube-s" | "livejournal-s" | "orkut-s" | "friendster-s")
    }) {
        if let Some((l_f1, _)) = r.baseline_scores[1] {
            let mark = if r.str_scores.0 > l_f1 { "STR wins" } else { "Louvain wins" };
            println!(
                "  {:<16} STR F1 {:.2} vs Louvain {:.2}  → {mark}",
                r.name, r.str_scores.0, l_f1
            );
        }
    }
    println!(
        "\npaper claim: Louvain/OSLOM lead on Amazon/DBLP; STR equal or \
         better on the large graphs (see EXPERIMENTS.md for the SCD caveat)"
    );
}
