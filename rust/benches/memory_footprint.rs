//! Bench M1 — the §4.4 memory-consumption experiment.
//!
//! Two accountings per workload: the analytic model (paper's own
//! numbers: 16 B/edge stored vs 16 B/node sketch) and the live heap
//! measured by the counting allocator while the algorithm actually runs.

use streamcom::bench::memory::{
    edge_list_bytes, fmt_bytes, sketch_bytes, CountingAllocator,
};
use streamcom::bench::report::Table;
use streamcom::bench::workloads;
use streamcom::coordinator::algorithm::{StrConfig, StreamingClusterer};
use streamcom::graph::generators::presets::SNAP_PRESETS;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator::new();

fn main() {
    let scale: f64 = std::env::var("SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(streamcom::bench::workloads::DEFAULT_SCALE);
    println!("# M1: memory accounting at scale {scale}\n");

    let mut t = Table::new(
        "M1 — memory consumption (paper §4.4)",
        &[
            "dataset", "|V|", "|E|", "edge list", "sketch (analytic)",
            "sketch (measured)", "ratio",
        ],
    );
    // paper reference rows for context
    let mut paper = Table::new(
        "paper reference (full-size SNAP)",
        &["dataset", "edge list", "STR measured"],
    );
    paper.push_row(vec!["Amazon".into(), "14.8 MB".into(), "8.1 MB".into()]);
    paper.push_row(vec!["Friendster".into(), "28.9 GB".into(), "1.6 GB".into()]);

    for preset in &SNAP_PRESETS {
        let g = workloads::load_preset(preset, scale, true);
        let el = edge_list_bytes(g.m() as u64);
        let sk = sketch_bytes(g.n() as u64);

        // measured: live heap delta attributable to the clusterer state
        let before = ALLOC.live_bytes();
        let mut c = StreamingClusterer::new(g.n(), StrConfig::new(256));
        c.process_chunk(&g.edges.edges);
        let after = ALLOC.live_bytes();
        let measured = after.saturating_sub(before);
        assert_eq!(c.state.memory_bytes() as u64, sk);

        t.push_row(vec![
            g.name.clone(),
            g.n().to_string(),
            g.m().to_string(),
            fmt_bytes(el),
            fmt_bytes(sk),
            fmt_bytes(measured),
            format!("{:.1}x", el as f64 / sk as f64),
        ]);
        drop(c);
    }
    println!("{}", t.render());
    println!("{}", paper.render());
    println!(
        "paper claim: the streaming sketch is a small fraction of the \
         memory needed just to STORE the edges (the baselines' floor)"
    );
}
