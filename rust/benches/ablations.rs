//! Bench A1 — ablations on the algorithm's design choices (DESIGN.md):
//!
//! * threshold form  — paper's `both ≤ v_max` vs `sum` vs `smaller-only`
//! * tie-break       — paper's deterministic j→i vs i→j vs randomised
//! * condition basis — community *volume* (paper) vs community *size*
//! * dynamic churn   — quality of the §5 insert+delete extension as the
//!   churn rate grows
//!
//! The paper fixes each of these choices with a line of justification;
//! the ablation shows they are the right defaults.

use streamcom::bench::report::Table;
use streamcom::bench::workloads;
use streamcom::coordinator::algorithm::{
    StrConfig, StreamingClusterer, ThresholdRule, TieBreak,
};
use streamcom::coordinator::dynamic::{DynamicClusterer, Event};
use streamcom::graph::generators::presets::SNAP_PRESETS;
use streamcom::metrics::f1::average_f1_labels;
use streamcom::metrics::nmi::nmi_labels;
use streamcom::util::rng::Xoshiro256;

fn main() {
    let scale: f64 = std::env::var("SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);
    let g = workloads::load_preset(&SNAP_PRESETS[2], scale, true); // youtube-s
    let truth = g.truth.to_labels(g.n());
    let v_max = streamcom::bench::table1::select_v_max(&g);
    println!(
        "# A1: ablations on {} (n={}, m={}, v_max={v_max})\n",
        g.name,
        g.n(),
        g.m()
    );

    let score = |cfg: StrConfig| {
        let mut c = StreamingClusterer::new(g.n(), cfg);
        let t0 = std::time::Instant::now();
        c.process_chunk(&g.edges.edges);
        let secs = t0.elapsed().as_secs_f64();
        let labels = c.labels();
        (
            average_f1_labels(&labels, &truth),
            nmi_labels(&labels, &truth),
            secs,
            c.stats,
        )
    };

    let mut t = Table::new(
        "A1 — decision-rule ablations",
        &["variant", "F1", "NMI", "ms", "joins", "rejects"],
    );
    let mut push = |name: &str, cfg: StrConfig| {
        let (f1, nmi, secs, stats) = score(cfg);
        t.push_row(vec![
            name.to_string(),
            format!("{f1:.3}"),
            format!("{nmi:.3}"),
            format!("{:.2}", secs * 1e3),
            stats.joins.to_string(),
            stats.threshold_rejects.to_string(),
        ]);
    };

    let base = StrConfig::new(v_max);
    push("paper (both≤vmax, j→i, volume)", base.clone());

    let mut c = base.clone();
    c.threshold = ThresholdRule::SumAtMost;
    push("threshold: sum≤2vmax", c);

    let mut c = base.clone();
    c.threshold = ThresholdRule::SmallerAtMost;
    push("threshold: smaller≤vmax", c);

    let mut c = base.clone();
    c.tie_break = TieBreak::IToJ;
    push("tie-break: i→j", c);

    let mut c = base.clone();
    c.tie_break = TieBreak::Random;
    c.seed = 1;
    push("tie-break: random", c);

    let mut c = base.clone();
    c.size_condition = true;
    push("condition on size not volume", c);

    // extension: two-pass coarse-graph refinement (coordinator::refine)
    // in both regimes — on the calibrated v_max (where coarse Louvain
    // over-merges against small ground-truth communities and hurts: the
    // volume threshold was doing real work) and on a deliberately
    // fragmenting v_max/8 (where the merge repair is what you want)
    for (name, vm) in [
        ("extension: + refine (calibrated vmax)", v_max),
        ("extension: + refine (vmax/8, fragmented)", (v_max / 8).max(2)),
    ] {
        let mut cl = StreamingClusterer::new(g.n(), StrConfig::new(vm));
        let t0 = std::time::Instant::now();
        cl.process_chunk(&g.edges.edges);
        let base_labels = cl.labels();
        let labels =
            streamcom::coordinator::refine::refine_two_pass(&g.edges.edges, &base_labels, 7);
        let secs = t0.elapsed().as_secs_f64();
        t.push_row(vec![
            name.into(),
            format!(
                "{:.3} (from {:.3})",
                average_f1_labels(&labels, &truth),
                average_f1_labels(&base_labels, &truth)
            ),
            format!("{:.3}", nmi_labels(&labels, &truth)),
            format!("{:.2}", secs * 1e3),
            cl.stats.joins.to_string(),
            cl.stats.threshold_rejects.to_string(),
        ]);
    }

    println!("{}", t.render());

    // dynamic churn: insert the stream, then apply churn (delete random
    // live edge + insert a fresh random edge) at increasing rates
    let mut t = Table::new(
        "A1b — dynamic extension under churn",
        &["churn (events/edge)", "F1", "NMI", "live edges"],
    );
    for churn in [0.0, 0.1, 0.3, 0.6] {
        let mut d = DynamicClusterer::new(g.n(), StrConfig::new(v_max));
        let mut live = Vec::new();
        for &e in &g.edges.edges {
            d.apply(Event::Insert(e)).unwrap();
            live.push(e);
        }
        let mut rng = Xoshiro256::new(0xC0DE);
        let events = (g.m() as f64 * churn) as usize;
        for _ in 0..events {
            // delete one random live edge, insert one random edge
            let idx = rng.range(0, live.len());
            let gone = live.swap_remove(idx);
            d.apply(Event::Delete(gone)).unwrap();
            let u = rng.range(0, g.n()) as u32;
            let mut v = rng.range(0, g.n()) as u32;
            if u == v {
                v = (v + 1) % g.n() as u32;
            }
            let e = streamcom::graph::edge::Edge::new(u, v);
            d.apply(Event::Insert(e)).unwrap();
            live.push(e);
        }
        let labels = d.labels();
        t.push_row(vec![
            format!("{churn:.1}"),
            format!("{:.3}", average_f1_labels(&labels, &truth)),
            format!("{:.3}", nmi_labels(&labels, &truth)),
            d.live_edges().to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("expectation: paper defaults lead; quality degrades gracefully with churn");
}
