//! Bench T1 — regenerates the paper's Table 1 (execution times) plus
//! the T1b `cat` comparison. `cargo bench --bench table1_runtime`
//! (env `SCALE=0.2` to change workload scale).

use streamcom::bench::report::fmt_secs;
use streamcom::bench::table1::{run, speedup_vs_fastest_baseline, Table1Config};

fn main() {
    let scale: f64 = std::env::var("SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(streamcom::bench::workloads::DEFAULT_SCALE);
    let cfg = Table1Config { scale, ..Default::default() };
    eprintln!("# T1: generating workloads at scale {scale} (cached under target/workloads)");
    let (table, rows) = run(&cfg);
    println!("{}", table.render());

    println!("paper-shape checks:");
    for r in &rows {
        let ratio = r.str_secs / r.readonly_secs.max(1e-12);
        let speedup = speedup_vs_fastest_baseline(r)
            .map(|s| format!("{s:.1}x"))
            .unwrap_or_else(|| "-".into());
        println!(
            "  {:<16} STR {:>8}  read {:>8}  STR/read {:>5.1}x  speedup-vs-fastest-baseline {:>8}",
            r.name,
            fmt_secs(r.str_secs),
            fmt_secs(r.readonly_secs),
            ratio,
            speedup
        );
    }
    println!(
        "\npaper claim: STR >10x faster than SCD/Louvain on every graph; \
         STR within ~2x of the raw read on the largest graph"
    );
}
