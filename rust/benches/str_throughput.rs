//! Bench P1 — STR streaming throughput vs the readonly lower bound,
//! across transports (memory / chunked pipeline) and the parallel
//! coordinator. This is the §Perf primary harness.

use streamcom::bench::framework::{bench, black_box, Budget};
use streamcom::bench::readonly::{readonly_file_binary, readonly_file_text, readonly_pass};
use streamcom::bench::workloads;
use streamcom::coordinator::algorithm::{StrConfig, StreamingClusterer};
use streamcom::coordinator::parallel::{run_parallel, ParallelConfig};
use streamcom::coordinator::sweep::MultiSweep;
use streamcom::graph::generators::presets::SNAP_PRESETS;
use streamcom::graph::io;
use streamcom::stream::chunk::{ChunkConfig, ChunkStream};
use streamcom::stream::source::{BinaryFileSource, OwnedMemorySource, TextFileSource};

fn main() {
    let scale: f64 = std::env::var("SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);
    // LiveJournal-shaped: the paper's "large but fits everywhere" row
    let g = workloads::load_preset(&SNAP_PRESETS[3], scale, true);
    let m = g.m() as f64;
    println!(
        "workload {}: n={} m={} (scale {scale})\n",
        g.name,
        g.n(),
        g.m()
    );

    let budget = Budget::heavy();
    let report = |name: &str, secs: f64| {
        println!("{:<28} {:>9.4}s   {:>7.1} Medges/s", name, secs, m / secs / 1e6);
    };

    let s = bench("readonly", budget, || {
        black_box(readonly_pass(&g.edges.edges));
    });
    report("readonly (cat-equivalent)", s.median_secs());
    let readonly = s.median_secs();

    let s = bench("str", budget, || {
        let mut c = StreamingClusterer::new(g.n(), StrConfig::new(256));
        c.process_chunk(&g.edges.edges);
        black_box(c.labels().len());
    });
    report("STR sequential (memory)", s.median_secs());
    let str_mem = s.median_secs();

    let s = bench("str-pipeline", budget, || {
        let src = OwnedMemorySource::new(g.edges.edges.clone());
        let stream = ChunkStream::spawn(src, ChunkConfig::default());
        let mut c = StreamingClusterer::new(g.n(), StrConfig::new(256));
        while let Some(chunk) = stream.next_chunk() {
            c.process_chunk(&chunk);
        }
        black_box(c.state.edges_processed);
    });
    report("STR chunked pipeline", s.median_secs());

    for shards in [2, 4, 8] {
        let s = bench("str-parallel", budget, || {
            let res = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(shards, 256));
            black_box(res.state.edges_processed);
        });
        report(&format!("STR sharded x{shards} (distribution mode)"), s.median_secs());
    }

    for threads in [2, 4, 8] {
        let s = bench("str-concurrent", budget, || {
            let sk = streamcom::coordinator::parallel::run_concurrent(
                g.n(),
                &g.edges.edges,
                256,
                threads,
            );
            black_box(sk.edges_processed());
        });
        report(&format!("STR concurrent x{threads} (atomic sketch)"), s.median_secs());
    }

    let s = bench("sweep8", budget, || {
        let mut sweep = MultiSweep::new(g.n(), MultiSweep::geometric_ladder(16, 8));
        sweep.process_chunk(&g.edges.edges);
        black_box(sweep.edges_processed);
    });
    report("multi-sweep (A=8)", s.median_secs());

    // --- T1b: the paper's actual `cat` comparison is against *files* —
    // its 152s cat vs 241s STR on Friendster both include reading the
    // edge list from disk. Reproduce that on both transports.
    let dir = std::env::temp_dir();
    let txt = dir.join(format!("sc_tp_{}.txt", std::process::id()));
    let bin = dir.join(format!("sc_tp_{}.bin", std::process::id()));
    io::write_text_edges(&txt, &g.edges).unwrap();
    io::write_binary_edges(&bin, &g.edges).unwrap();

    println!();
    let s = bench("cat-text", budget, || {
        black_box(readonly_file_text(&txt).unwrap());
    });
    report("cat text file", s.median_secs());
    let cat_text = s.median_secs();

    let s = bench("str-text", budget, || {
        let mut c = StreamingClusterer::new(g.n(), StrConfig::new(256));
        let mut src = TextFileSource::open(&txt).unwrap();
        c.run(&mut src, 65_536);
        black_box(c.state.edges_processed);
    });
    report("STR from text file", s.median_secs());
    let str_text = s.median_secs();

    let s = bench("cat-bin", budget, || {
        black_box(readonly_file_binary(&bin).unwrap());
    });
    report("cat binary file", s.median_secs());
    let cat_bin = s.median_secs();

    let s = bench("str-bin", budget, || {
        let mut c = StreamingClusterer::new(g.n(), StrConfig::new(256));
        let mut src = BinaryFileSource::open(&bin).unwrap();
        c.run(&mut src, 65_536);
        black_box(c.state.edges_processed);
    });
    report("STR from binary file", s.median_secs());
    let str_bin = s.median_secs();

    std::fs::remove_file(&txt).ok();
    std::fs::remove_file(&bin).ok();

    println!(
        "\nT1b (paper: STR ≈ 1.6x cat on Friendster):\n  \
         STR/cat text   {:.2}x\n  \
         STR/cat binary {:.2}x\n  \
         STR/readonly (pure DRAM pass, no paper analogue) {:.2}x",
        str_text / cat_text,
        str_bin / cat_bin,
        str_mem / readonly
    );
}
